"""Telemetry layer: probe math, the bitwise no-change contract across
all five engines, the Chrome-trace exporter and the run manifests.

The load-bearing guarantee is differential: every engine must produce
**bitwise-identical** non-telemetry outputs with probes off and probes
ON (the ``tlm_*`` carry keys are never read by summary paths), and
``telemetry=None`` must add zero carry keys.  Unit tests pin the bin /
forward-fill / histogram semantics shared by the device probes and
their pure-Python twin (:class:`repro.telemetry.probes.PyProbes`).
"""

import json
import math

import numpy as np
import pytest

from repro.core.planning import solve_bundled_lp
from repro.core.policies import gate_and_route
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass
from repro.data.traces import TraceConfig, synth_azure_trace
from repro.telemetry.manifest import (MANIFEST_SCHEMA_VERSION, append_record,
                                      file_digest, payload_digest,
                                      read_records, run_record,
                                      validate_record)
from repro.telemetry.probes import (CTMC_PROBE_KEYS, PROBES, ProbeSpec,
                                    PyProbes, extract_probes,
                                    hist_attainment, hist_edges,
                                    hist_percentile, resolve_probe_spec)
from repro.telemetry.trace import (TRACE_SCHEMA_VERSION, lifecycle_events,
                                   replan_events, trace_payload,
                                   validate_trace, write_trace)

PRIM = ServicePrimitives()
PRICE = Pricing(0.1, 0.2)
CLASSES = [WorkloadClass("chat", 512, 768, 0.2),
           WorkloadClass("agent", 1024, 1024, 0.1)]
N = 8
HORIZON = 30.0


@pytest.fixture(scope="module")
def plan():
    return solve_bundled_lp(CLASSES, PRIM, PRICE)


@pytest.fixture(scope="module")
def trace():
    t = synth_azure_trace(TraceConfig(horizon=HORIZON, base_rate=1.5,
                                      compression=0.3, seed=5))
    for r in t:
        r.patience = float("inf")
    return t


def _same(a, b):
    """Bitwise-or-both-NaN scalar equality."""
    fa, fb = float(a), float(b)
    return fa == fb or (math.isnan(fa) and math.isnan(fb))


# ---------------------------------------------------------------------------
# ProbeSpec / resolve_probe_spec
# ---------------------------------------------------------------------------


def test_probe_spec_validation():
    ProbeSpec(n_bins=1, n_hist=2)  # minimal legal spec
    with pytest.raises(ValueError, match="n_bins"):
        ProbeSpec(n_bins=0)
    with pytest.raises(ValueError, match="n_bins"):
        ProbeSpec(n_hist=1)
    with pytest.raises(ValueError, match="hist_min"):
        ProbeSpec(hist_min=0.0)
    with pytest.raises(ValueError, match="hist_min"):
        ProbeSpec(hist_min=2.0, hist_max=1.0)
    # frozen + hashable: usable as a jit static
    assert hash(ProbeSpec()) == hash(ProbeSpec())


def test_resolve_probe_spec_coercions():
    assert resolve_probe_spec(None) is None
    assert resolve_probe_spec(False) is None
    assert resolve_probe_spec(True) == ProbeSpec()
    assert resolve_probe_spec({"n_bins": 8}) == ProbeSpec(n_bins=8)
    spec = ProbeSpec(n_hist=16)
    assert resolve_probe_spec(spec) is spec
    with pytest.raises(TypeError, match="telemetry"):
        resolve_probe_spec(42)


def test_probe_registry_keys_are_prefixed():
    for name, d in PROBES.items():
        assert d.key.startswith("tlm_"), (name, d.key)
    assert set(CTMC_PROBE_KEYS) <= {d.key for d in PROBES.values()}


# ---------------------------------------------------------------------------
# histogram math
# ---------------------------------------------------------------------------


def test_hist_percentile_and_attainment():
    spec = ProbeSpec(n_hist=4, hist_min=1.0, hist_max=4.0)
    edges = hist_edges(spec)  # 3 interior edges: 1, 2, 4
    assert edges.shape == (3,)
    np.testing.assert_allclose(edges, [1.0, 2.0, 4.0])
    assert math.isnan(hist_percentile(np.zeros(4), edges, 95))
    assert math.isnan(hist_attainment(np.zeros(4), edges, 1.0))
    # all mass in one interior bucket -> percentile interpolates inside
    h = np.array([0.0, 10.0, 0.0, 0.0])  # bucket [1, 2)
    assert 1.0 <= hist_percentile(h, edges, 50) <= 2.0
    assert hist_percentile(h, edges, 0.1) < hist_percentile(h, edges, 99)
    # attainment is conservative: counts whole buckets by upper edge
    h2 = np.array([5.0, 5.0, 0.0, 0.0])
    assert hist_attainment(h2, edges, 2.0) == pytest.approx(1.0)
    assert hist_attainment(h2, edges, 1.5) == pytest.approx(0.5)
    assert hist_attainment(h2, edges, 0.5) == 0.0


def test_extract_probes_ffill_and_batch_reduction():
    spec = ProbeSpec(n_bins=4, n_hist=4, hist_min=1.0, hist_max=4.0)
    nb = spec.n_bins

    def rep(ev, q):
        raw = {d.key: np.zeros(nb) for d in PROBES.values()}
        raw["tlm_q"] = np.asarray(q, dtype=float)[:, None]
        raw["tlm_adm"] = np.zeros((nb, 1))
        raw["tlm_ev"] = np.asarray(ev, dtype=float)
        raw["tlm_busy_srv"] = np.zeros(2)
        raw["tlm_ttft"] = np.array([1.0, 0.0, 0.0, 0.0])
        raw["tlm_e2e"] = np.array([0.0, 2.0, 0.0, 0.0])
        return raw

    # single replication: bin 2 saw no event -> forward-fill from bin 1
    one = rep(ev=[1, 1, 0, 1], q=[3, 5, 0, 2])
    out = extract_probes(one, spec, horizon=8.0, n_servers=2)
    np.testing.assert_array_equal(out["queue_depth"][:, 0], [3, 5, 5, 2])
    assert out["bin_width"] == 2.0
    np.testing.assert_array_equal(out["t_bins"], [1, 3, 5, 7])
    # batched: ffill per replication BEFORE averaging; counters sum
    two = {k: np.stack([one[k], rep(ev=[1, 0, 0, 0], q=[1, 0, 0, 0])[k]])
           for k in one}
    out2 = extract_probes(two, spec, horizon=8.0, n_servers=2)
    np.testing.assert_array_equal(out2["queue_depth"][:, 0],
                                  [2, 3, 3, 1.5])
    np.testing.assert_array_equal(out2["events"], [2, 1, 0, 1])
    np.testing.assert_array_equal(out2["ttft_hist"],
                                  2 * one["tlm_ttft"])
    assert out2["ttft_p50"] <= 1.0  # all mass in the underflow bucket


def test_extract_probes_rejects_bare_carry():
    with pytest.raises(KeyError, match="telemetry"):
        extract_probes({"t": np.zeros(3)}, ProbeSpec(), horizon=1.0,
                       n_servers=1)


def test_pyprobes_semantics():
    spec = ProbeSpec(n_bins=4, n_hist=4, hist_min=1.0, hist_max=4.0)
    p = PyProbes(spec, horizon=8.0, n_servers=2, n_classes=1)
    p.sample(1.0, queue_depth=[2.0], decode_occupancy=3.0,
             prefill_in_flight=1.0, busy=[True, False])
    p.sample(3.0, queue_depth=[4.0], decode_occupancy=1.0,
             prefill_in_flight=0.0, busy=[True, True])
    p.count(3.0, admit_class=0, drops=2.0)
    p.observe_ttft(1.5)   # bucket [1, 2)
    p.observe_e2e(100.0)  # overflow bucket
    raw = p.raw()
    # last-value in bin 0 (t=1.0) and bin 1 (t=3.0)
    np.testing.assert_array_equal(raw["tlm_q"][:, 0], [2, 4, 0, 0])
    np.testing.assert_array_equal(raw["tlm_ev"], [1, 1, 0, 0])
    np.testing.assert_array_equal(raw["tlm_adm"][:, 0], [0, 1, 0, 0])
    np.testing.assert_array_equal(raw["tlm_drop"], [0, 2, 0, 0])
    # busy integral: server 0 busy over [1, 3) -> 2s in bin 0 (t0's bin)
    np.testing.assert_array_equal(raw["tlm_busy_srv"], [2.0, 0.0])
    np.testing.assert_array_equal(raw["tlm_busy_bin"], [2, 0, 0, 0])
    np.testing.assert_array_equal(raw["tlm_ttft"], [0, 1, 0, 0])
    np.testing.assert_array_equal(raw["tlm_e2e"], [0, 0, 0, 1])
    out = p.extract()  # renders through the same extractor
    np.testing.assert_array_equal(out["queue_depth"][:, 0], [2, 4, 4, 4])


# ---------------------------------------------------------------------------
# bitwise no-change contract, engine by engine
# ---------------------------------------------------------------------------


@pytest.mark.sim
def test_engine_sim_bitwise_invariant(plan, trace):
    from repro.serving.engine_sim import ClusterEngine, EngineConfig

    def summary(tlm):
        eng = ClusterEngine(CLASSES, gate_and_route(plan),
                            EngineConfig(PRIM, PRICE, N, seed=3,
                                         telemetry=tlm))
        return eng.run(trace, horizon=HORIZON)

    off, on = summary(None), summary(True)
    assert off.telemetry is None
    for k, v in off.summary().items():
        assert _same(v, on.summary()[k]), k
    tl = on.telemetry
    assert tl["e2e_hist"].sum() == on.summary()["completions"]
    assert tl["events"].sum() > 0


@pytest.mark.sim
def test_engine_jax_bitwise_invariant(plan, trace):
    from repro.serving.engine_jax import ClusterEngineJAX
    from repro.serving.engine_sim import EngineConfig

    def raw(tlm):
        eng = ClusterEngineJAX(CLASSES, gate_and_route(plan),
                               EngineConfig(PRIM, PRICE, N), trace,
                               horizon=HORIZON, fastforward=True,
                               telemetry=tlm)
        return eng, eng.run_raw(0)

    eng_off, off = raw(None)
    eng_on, on = raw(True)
    # probes off adds ZERO carry keys; probes on adds exactly the tlm_*
    extra = set(on) - set(off)
    assert extra == {d.key for d in PROBES.values()}
    for k in off:  # every shared output is bitwise identical
        np.testing.assert_array_equal(np.asarray(off[k]),
                                      np.asarray(on[k]), err_msg=k)
    s = eng_on._summary({k: np.asarray(v) for k, v in on.items()})
    tl = eng_on.telemetry_from_raw(on)
    assert tl["e2e_hist"].sum() == s["completions"]
    assert tl["events"].sum() == s["n_events"]
    assert np.isfinite(tl["ttft_p95"])
    # batched raw reduces: counters sum over the replication axis
    braw = eng_on.run_batch_raw([0, 1], placement="vmap")
    btl = eng_on.telemetry_from_raw(braw)
    assert btl["events"].sum() >= tl["events"].sum()


@pytest.mark.sim
def test_engine_stream_bitwise_invariant(plan, trace):
    from repro.serving.engine_stream import (StreamingEngineJAX,
                                             TraceChunkSource)
    from repro.serving.engine_sim import EngineConfig

    def run(tlm):
        eng = StreamingEngineJAX(CLASSES, gate_and_route(plan),
                                 EngineConfig(PRIM, PRICE, N),
                                 horizon=HORIZON, window=512,
                                 telemetry=tlm)
        return eng.run_stream(TraceChunkSource(trace, chunk_size=64),
                              seed=0)

    off, on = run(None), run(True)
    assert "telemetry" not in off
    for k, v in off.items():
        if k == "window_occupancy":
            assert v == on[k]
        else:
            assert _same(v, on[k]), k
    tl = on["telemetry"]
    # splice folds + residual fold observe each request exactly once
    assert tl["e2e_hist"].sum() == off["completions"]
    assert tl["ttft_hist"].sum() >= off["completions"]


@pytest.mark.sim
def test_ctmc_python_bitwise_invariant(plan):
    from repro.core.simulator import CTMCSimulator

    def result(tlm):
        sim = CTMCSimulator(CLASSES, PRIM, PRICE, gate_and_route(plan),
                            n=N, seed=11, telemetry=tlm)
        return sim.run(20.0, warmup=2.0)

    off, on = result(None), result(True)
    assert off.telemetry is None and on.telemetry is not None
    assert off.revenue == on.revenue
    assert off.n_events == on.n_events
    np.testing.assert_array_equal(off.completions, on.completions)
    np.testing.assert_array_equal(off.avg_x, on.avg_x)
    assert on.telemetry["events"].sum() > 0


@pytest.mark.sim
def test_ctmc_jax_bitwise_invariant(plan):
    from repro.core.ctmc_jax import UniformizedCTMC

    def raw(tlm):
        sim = UniformizedCTMC(CLASSES, PRIM, PRICE, gate_and_route(plan),
                              n=N, horizon=20.0, warmup=2.0,
                              telemetry=tlm)
        return sim, sim.run_batch_raw([0, 1], placement="vmap")

    sim_off, off = raw(None)
    sim_on, on = raw(True)
    extra = set(on) - set(off)
    assert extra == set(CTMC_PROBE_KEYS)  # aggregate subset only
    for k in off:
        np.testing.assert_array_equal(np.asarray(off[k]),
                                      np.asarray(on[k]), err_msg=k)
    tl = sim_on.telemetry_from_raw(on)
    assert tl["events"].sum() > 0
    assert "ttft_p95" not in tl  # no per-request identity in the CTMC


# ---------------------------------------------------------------------------
# trace-event exporter
# ---------------------------------------------------------------------------


def test_lifecycle_events_phases():
    # Python-engine record: all three phase boundaries -> 3 spans
    full = {"rid": 4, "cls": "chat", "t_arr": 1.0, "t_admit": 2.0,
            "t_prefill_done": 3.0, "t_first": 3.5, "t_last": 6.0,
            "state": "done"}
    # JAX-engine record: arrival/first/last only -> merged wait+prefill
    merged = {"rid": 5, "cls": "agent", "t_arr": 1.0, "t_first": 4.0,
              "t_last": 4.0}
    # still queued at horizon: no spans beyond nothing-finite
    queued = {"rid": 6, "cls": "chat", "t_arr": 2.0,
              "t_first": float("inf"), "t_last": float("-inf")}
    evs = lifecycle_events([full, merged, queued])
    names = [(e["name"], e["tid"]) for e in evs]
    assert names == [("queue", 4), ("prefill", 4), ("decode", 4),
                     ("wait+prefill", 5), ("decode", 5)]
    q = evs[0]
    assert q["ph"] == "X" and q["ts"] == 1e6 and q["dur"] == 1e6
    assert q["args"]["state"] == "done"
    assert all(e["pid"] == 1 for e in evs)


def test_replan_events_and_payload_roundtrip(tmp_path):
    evs = replan_events([1.5, (3.0, {"epoch": 2, "n": 8})])
    assert [e["ph"] for e in evs] == ["i", "i"]
    assert evs[1]["args"]["epoch"] == 2
    assert all(e["pid"] == 2 for e in evs)
    payload = trace_payload(evs, source="test")
    assert payload["otherData"]["schema_version"] == TRACE_SCHEMA_VERSION
    p = write_trace(tmp_path / "t.json", evs, source="test")
    assert validate_trace(p) == []
    assert validate_trace(json.loads(p.read_text())) == []


def test_validate_trace_catches_malformed():
    assert validate_trace([]) != []
    assert validate_trace({"nope": 1}) != []
    bad_ph = {"traceEvents": [{"name": "x", "ph": "Z", "pid": 1,
                               "tid": 0}]}
    assert any("ph" in e for e in validate_trace(bad_ph))
    bad_ts = {"traceEvents": [{"name": "x", "ph": "i", "pid": 1, "tid": 0,
                               "ts": float("nan")}]}
    assert any("ts" in e for e in validate_trace(bad_ts))
    bad_dur = {"traceEvents": [{"name": "x", "ph": "X", "pid": 1,
                                "tid": 0, "ts": 0.0, "dur": -1.0}]}
    assert any("dur" in e for e in validate_trace(bad_dur))
    bad_pid = {"traceEvents": [{"name": "decode", "ph": "X", "pid": 7,
                                "tid": 0, "ts": 0.0, "dur": 1.0}]}
    assert any("pid" in e for e in validate_trace(bad_pid))
    future = {"traceEvents": [],
              "otherData": {"schema_version": TRACE_SCHEMA_VERSION + 1}}
    assert any("schema_version" in e for e in validate_trace(future))


@pytest.mark.sim
def test_engine_lifecycle_records_render(plan, trace):
    from repro.serving.engine_sim import ClusterEngine, EngineConfig

    eng = ClusterEngine(CLASSES, gate_and_route(plan),
                        EngineConfig(PRIM, PRICE, N, seed=3,
                                     telemetry=True))
    eng.run(trace, horizon=HORIZON)
    evs = lifecycle_events(eng.lifecycle_records(limit=50))
    assert evs and validate_trace({"traceEvents": evs}) == []
    assert {"queue", "prefill", "decode"} <= {e["name"] for e in evs}
    # a probes-off engine refuses: records need the telemetry run
    bare = ClusterEngine(CLASSES, gate_and_route(plan),
                         EngineConfig(PRIM, PRICE, N, seed=3))
    bare.run(trace, horizon=HORIZON)
    with pytest.raises(ValueError, match="telemetry"):
        bare.lifecycle_records()


# ---------------------------------------------------------------------------
# run manifests
# ---------------------------------------------------------------------------


def test_run_record_roundtrip(tmp_path):
    art = tmp_path / "out.json"
    art.write_text('{"x": 1}')
    rec = run_record(kind="bench", name="t", wall_s=1.25,
                     extra={"mode": "quick"},
                     artifacts={str(art): file_digest(art)})
    assert rec["schema_version"] == MANIFEST_SCHEMA_VERSION
    assert validate_record(rec) == []
    mpath = append_record(rec, tmp_path / "runs.jsonl")
    assert append_record(rec, mpath) == mpath  # JSONL appends
    loaded = list(read_records(mpath))
    assert len(loaded) == 2 and loaded[0] == rec


def test_validate_record_rejects_malformed():
    assert validate_record({}) != []
    assert validate_record({"schema_version": 1}) != []
    rec = run_record(kind="bench", name="t")
    bad = dict(rec, kind="banana")
    assert any("kind" in e for e in validate_record(bad))
    bad = dict(rec, schema_version=MANIFEST_SCHEMA_VERSION + 1)
    assert any("schema_version" in e for e in validate_record(bad))
    bad = dict(rec, wall_s="fast")
    assert any("wall_s" in e for e in validate_record(bad))
    with pytest.raises(ValueError):
        append_record(dict(rec, kind="banana"), "/tmp/never-written.jsonl")


def test_payload_digest_excludes_manifest_key():
    payload = {"a": 1, "b": [1.0, 2.0]}
    d = payload_digest(payload)
    assert d == payload_digest(dict(payload))  # stable
    assert d == payload_digest({**payload, "manifest": {"kind": "bench"}})
    assert d != payload_digest({**payload, "a": 2})
