"""Per-architecture smoke tests: reduced config of the same family runs one
forward/train step on CPU with correct output shapes and no NaNs, and the
prefill->decode path is consistent with the teacher-forced forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import model as M


def _stub_inputs(cfg, B):
    kw = {}
    if cfg.encoder is not None:
        kw["enc_frames"] = 0.01 * jnp.ones(
            (B, cfg.encoder.n_frames, cfg.encoder.d_model), jnp.float32)
    if cfg.vision is not None:
        kw["prefix_embeds"] = 0.01 * jnp.ones(
            (B, cfg.vision.n_patches, cfg.d_model), jnp.float32)
    return kw


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step(arch):
    cfg = get_config(arch, reduced=True)
    params = M.init_model(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kw = _stub_inputs(cfg, B)
    logits, _ = M.forward_train(cfg, params, tokens, **kw)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss = M.loss_fn(cfg, params, tokens, tokens, **kw)
    assert bool(jnp.isfinite(loss))
    # gradient exists and is finite on a couple of leaves
    g = jax.grad(lambda p: M.loss_fn(cfg, p, tokens, tokens, **kw))(params)
    leaves = jax.tree.leaves(g)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves[:5])


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_prefill_decode_consistency(arch):
    """greedy(decode(prefix)) must equal greedy(teacher-forced logits).

    MoE archs run with a drop-free capacity factor: capacity-truncated
    dispatch is batch-composition-dependent by design (the standard TPU
    static-shape trade), so the prefill==train property only holds in the
    dropless regime.
    """
    cfg = get_config(arch, reduced=True)
    if cfg.moe is not None:
        import dataclasses
        cfg = cfg.replace(
            moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = M.init_model(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    key = jax.random.PRNGKey(2)
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    kw = _stub_inputs(cfg, B)
    full_logits, _ = M.forward_train(cfg, params, tokens, **kw)

    caches = M.init_cache(cfg, B, cfg.max_seq_len, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    pre_logits, caches = M.forward_prefill(cfg, params, tokens[:, :S], pos,
                                           caches, **kw)
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, 0]), np.asarray(full_logits[:, S - 1]),
        rtol=2e-3, atol=2e-3)

    # one decode step with the true next token
    prefix = cfg.vision.n_patches if cfg.vision is not None else 0
    dpos = jnp.full((B,), S + prefix, jnp.int32)
    dec_logits, _ = M.forward_decode(cfg, params, tokens[:, S:S + 1], dpos,
                                     caches)
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0]), np.asarray(full_logits[:, S]),
        rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["gemma2-2b", "mamba2-130m",
                                  "recurrentgemma-2b"])
def test_chunked_prefill_matches_full(arch):
    """Prefilling in two chunks must produce the same last logits."""
    cfg = get_config(arch, reduced=True)
    if cfg.ssm is not None:
        chunk = cfg.ssm.chunk
        S = 2 * chunk
        split = chunk
    else:
        S, split = 24, 12
    params = M.init_model(cfg, jax.random.PRNGKey(0))
    B = 2
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    c1 = M.init_cache(cfg, B, cfg.max_seq_len, jnp.float32)
    full, _ = M.forward_prefill(cfg, params, tokens, pos, c1)

    c2 = M.init_cache(cfg, B, cfg.max_seq_len, jnp.float32)
    _, c2 = M.forward_prefill(cfg, params, tokens[:, :split],
                              pos[:, :split], c2, continuation=True)
    two, _ = M.forward_prefill(cfg, params, tokens[:, split:],
                               pos[:, split:], c2, continuation=True)
    np.testing.assert_allclose(np.asarray(two), np.asarray(full),
                               rtol=3e-3, atol=3e-3)


def test_param_counts_match_published_sizes():
    """Full configs should land near their nameplate parameter counts."""
    expected = {
        "deepseek-v3-671b": (671e9, 0.10),
        "grok-1-314b": (314e9, 0.12),
        "deepseek-67b": (67e9, 0.10),
        "qwen2-0.5b": (0.494e9, 0.10),
        "gemma2-2b": (2.6e9, 0.20),
        "phi4-mini-3.8b": (3.8e9, 0.25),
        "recurrentgemma-2b": (2.7e9, 0.25),
        "mamba2-130m": (0.13e9, 0.25),
        "paligemma-3b": (2.9e9, 0.25),  # LM backbone (vision tower stubbed)
    }
    for arch, (target, tol) in expected.items():
        n = M.param_count(get_config(arch))
        assert abs(n - target) / target < tol, (arch, n, target)


def test_int8_kv_cache_close_to_fp():
    """kv_quant=True decode logits stay close to full-precision logits."""
    cfg = get_config("qwen2-0.5b", reduced=True)
    qcfg = cfg.replace(kv_quant=True)
    params = M.init_model(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S + 1), 0,
                                cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    outs = {}
    for name, c in (("fp", cfg), ("q", qcfg)):
        caches = M.init_cache(c, B, c.max_seq_len, jnp.float32)
        _, caches = M.forward_prefill(c, params, tokens[:, :S], pos, caches)
        lg, _ = M.forward_decode(c, params, tokens[:, S:S + 1],
                                 jnp.full((B,), S, jnp.int32), caches)
        outs[name] = np.asarray(lg)
    # int8 KV is an approximation: demand close logits + same argmax
    np.testing.assert_allclose(outs["q"], outs["fp"], atol=0.15, rtol=0.15)
    np.testing.assert_array_equal(outs["q"].argmax(-1), outs["fp"].argmax(-1))
