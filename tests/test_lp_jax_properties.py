"""Hypothesis property tests: lp_jax vs the simplex oracle.

Randomized feasible planning instances and general feasible-bounded LPs
must agree with ``linprog_max`` / ``solve_plan`` within the tolerance
documented in ``docs/PLANNING.md`` (relative 1e-6 on objectives).
Separate module from ``tests/test_lp_jax.py`` so the deterministic
corpus checks still run where hypothesis is absent (this whole module
importorskips, the ``tests/test_traces_tensor.py`` pattern).
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis")  # property tests need hypothesis; skip where absent
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.lp import linprog_max  # noqa: E402
from repro.core.lp_jax import linprog_max_jax  # noqa: E402
from repro.core.planning import SLISpec, solve_bundled_lp  # noqa: E402
from repro.core.planning_batch import solve_plan_batch  # noqa: E402
from repro.core.types import (Pricing, ServicePrimitives,  # noqa: E402
                              WorkloadClass, rate_arrays)

PRICE = Pricing(c_p=0.1, c_d=0.2)
REL_TOL = 1e-6


def rel_err(a, b):
    return abs(a - b) / (1.0 + abs(a))


@st.composite
def planning_instances(draw):
    """The randomized feasible instance family of tests/test_planning.py."""
    I = draw(st.integers(1, 4))
    classes = []
    for i in range(I):
        P = draw(st.floats(50, 4000))
        D = draw(st.floats(20, 2000))
        lam = draw(st.floats(0.01, 1.5))
        th = draw(st.floats(0.01, 0.5))
        classes.append(WorkloadClass(f"c{i}", P, D, lam, th))
    B = draw(st.integers(4, 32))
    return classes, ServicePrimitives(batch_cap=B)


@settings(max_examples=25, deadline=None)
@given(planning_instances())
def test_planner_matches_oracle_on_random_instances(inst):
    classes, prim = inst
    oracle = solve_bundled_lp(classes, prim, PRICE)
    pb = solve_plan_batch([classes], prim, PRICE)
    assert bool(pb.converged[0]), (pb.primal_res, pb.dual_res, pb.gap)
    sol = pb.solution(0)
    assert rel_err(oracle.revenue_rate, sol.revenue_rate) < REL_TOL
    # primal feasibility of the batched solution at the same scale
    arr = rate_arrays(classes, prim)
    np.testing.assert_allclose(
        arr["mu_p"] * sol.x + arr["theta"] * sol.qp, arr["lam"], atol=1e-5)
    assert sol.x.sum() <= 1 + 1e-6
    for v in (sol.x, sol.ym, sol.ys, sol.qp, sol.qd):
        assert np.all(v >= -1e-6)


@settings(max_examples=25, deadline=None)
@given(planning_instances())
def test_planner_pin_matches_oracle(inst):
    """Proposition 1's pinned variant solves to the same tolerance."""
    classes, prim = inst
    sli = SLISpec(pin_zero_decode_queue=True)
    oracle = solve_bundled_lp(classes, prim, PRICE, sli=sli)
    pb = solve_plan_batch([classes], prim, PRICE, sli=sli)
    assert bool(pb.converged[0])
    assert rel_err(oracle.revenue_rate, pb.revenue_rate[0]) < REL_TOL
    assert np.all(np.abs(pb.solution(0).qd) < 1e-6)


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_general_lps_match_oracle_and_strong_duality(data):
    """Feasible-bounded random LPs (the tests/test_lp.py family)."""
    n = data.draw(st.integers(2, 5))
    m = data.draw(st.integers(1, 4))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    c = rng.normal(size=n)
    A = np.vstack([rng.normal(size=(m, n)), np.ones((1, n))])
    b = np.concatenate([rng.uniform(0.5, 2.0, size=m), [5.0]])
    ref = linprog_max(c, A, b)
    got = linprog_max_jax(c, A, b)
    assert bool(got.converged), (got.primal_res, got.dual_res, got.gap)
    assert rel_err(ref.fun, got.fun) < REL_TOL
    # primal feasibility + strong duality of the IPM point
    assert np.all(A @ got.x <= b + 1e-6)
    assert np.all(got.x >= -1e-8)
    assert rel_err(got.fun, float(b @ got.dual_ub)) < 1e-5
