"""Training substrate tests: optimizer, train step, checkpoint/restart,
gradient compression, data determinism."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.training import (DataConfig, OptConfig, SyntheticLM,
                            init_train_state, make_train_step)
from repro.training.compress import dequantize_int8, quantize_int8
from repro.launch.train import preset_100m, run_training


def test_loss_decreases_small_model(tmp_path):
    cfg = get_config("qwen2-0.5b", reduced=True)
    opt = OptConfig(lr=2e-3, warmup_steps=5, total_steps=60)
    state = init_train_state(cfg, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(cfg, opt))
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, batch=8,
                                seq_len=64))
    losses = []
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


def test_microbatch_equals_full_batch_grads():
    """Grad accumulation over microbatches == single big batch (linearity)."""
    cfg = get_config("qwen2-0.5b", reduced=True)
    opt = OptConfig()
    state = init_train_state(cfg, jax.random.PRNGKey(0), opt)
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, batch=4,
                                seq_len=32))
    b = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
    s1, m1 = jax.jit(make_train_step(cfg, opt, microbatches=1))(state, b)
    s2, m2 = jax.jit(make_train_step(cfg, opt, microbatches=2))(state, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    l1 = jax.tree.leaves(s1["params"])
    l2 = jax.tree.leaves(s2["params"])
    for a, b_ in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-5)


def test_checkpoint_restart_resumes_exactly(tmp_path):
    """Kill/restart: resumed run produces the same final loss."""
    cfg = preset_100m().replace(n_layers=2, d_model=64, d_ff=128,
                                vocab_size=512)
    kw = dict(steps=8, batch=2, seq_len=32, ckpt_every=4, log_every=100)
    full = run_training(cfg, ckpt_dir=None, **kw)
    # run 8 steps with a checkpoint at 4, then "crash" and resume
    d = str(tmp_path / "ck")
    run_training(cfg, ckpt_dir=d, **dict(kw, steps=4))
    resumed = run_training(cfg, ckpt_dir=d, **kw)
    np.testing.assert_allclose(resumed["final_loss"], full["final_loss"],
                               rtol=1e-4)


def test_int8_error_feedback_roundtrip():
    x = np.random.default_rng(0).normal(size=(64, 64)).astype(np.float32)
    q, s = quantize_int8(jnp.asarray(x))
    back = dequantize_int8(q, s)
    # quantisation error bounded by scale/2 per element
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-6


def test_compressed_psum_preserves_mean_with_feedback():
    """Over repeated steps, error feedback keeps the compressed mean
    unbiased: accumulated residuals stay bounded."""
    import os
    from repro.training.compress import make_compressed_psum
    # single-device shard_map over a size-1 axis still exercises the path
    mesh = jax.make_mesh((1,), ("data",))
    f = make_compressed_psum(mesh, "data")
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(32,))
                          .astype(np.float32))}
    r = {"w": jnp.zeros((32,), jnp.float32)}
    fn = shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
    total = jnp.zeros((32,))
    for _ in range(50):
        mean, r = fn(g, r)
        total = total + mean["w"]
    # with error feedback, sum of outputs ~ 50 * g (residual bounded)
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g["w"]),
                               atol=2e-3)


def test_data_pipeline_deterministic_resume():
    cfg = DataConfig(vocab_size=1000, batch=2, seq_len=64, seed=3)
    a = SyntheticLM(cfg).batch_at(17)
    b = SyntheticLM(cfg).batch_at(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    # next-token alignment
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
