"""Hypothesis metamorphic properties for the engine hot path.

Companion to ``test_engine_diff.py`` (same module split as the other
``*_properties`` files: module-scope importorskip, so environments
without hypothesis skip these wholesale while the deterministic
differential families still run).

Properties: engine summaries are invariant to the multi-event block size
``k_events``; the on-device scenario stream emits a bitwise
chunk-size-invariant trace; lifecycle conservation and slot-capacity
laws hold on randomly drawn workloads under the fast-forward kernel.
"""

import numpy as np
import pytest

from repro.core.planning import SLISpec, solve_bundled_lp
from repro.core.policies import gate_and_route
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass
from repro.data.traces import (Request, TraceConfig, synth_azure_trace,
                               tensorize_trace, trace_class_means)
from repro.serving.engine_jax import ClusterEngineJAX
from repro.serving.engine_sim import EngineConfig

pytestmark = pytest.mark.sim

hypothesis = pytest.importorskip(
    "hypothesis")  # property tests need hypothesis; skip where absent
from hypothesis import given, settings, strategies as st  # noqa: E402

PRIM = ServicePrimitives()
PRICE = Pricing(0.1, 0.2)
N = 8
PAD = 512  # shared padded shape => one jit cache entry per leg

_MK_CACHE = {}


def _mk(seed, compression=0.25, horizon=25.0):
    key = (seed, compression, horizon)
    if key not in _MK_CACHE:
        trace = synth_azure_trace(TraceConfig(
            horizon=horizon, base_rate=2.0, compression=compression,
            seed=seed))
        assert len(trace) <= PAD
        means = trace_class_means(trace, 2)
        classes = [WorkloadClass(nm, m[0], m[1], m[2] / N, patience=3e-4)
                   for nm, m in zip(("code", "conv"), means)]
        plan = solve_bundled_lp(classes, PRIM, PRICE,
                                sli=SLISpec(pin_zero_decode_queue=True))
        _MK_CACHE[key] = (tensorize_trace(trace, pad_to=PAD), classes, plan)
    return _MK_CACHE[key]


def _jax(tt, classes, pol, horizon, **kw):
    return ClusterEngineJAX(classes, pol,
                            EngineConfig(PRIM, PRICE, n_servers=N),
                            tt, horizon=horizon, **kw)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 500), st.sampled_from([2, 3]))
def test_summary_k_invariance(seed, k):
    """Summaries are invariant to the block size k on random workloads
    (``n_steps`` counts blocks and is excluded by construction)."""
    tt, classes, plan = _mk(1000 + seed, compression=0.3, horizon=20.0)
    pol = gate_and_route(plan)
    a = _jax(tt, classes, pol, 20.0).run(0)
    b = _jax(tt, classes, pol, 20.0, k_events=k).run(0)
    assert set(a) == set(b)
    for key in a:
        if key == "n_steps":
            continue
        assert a[key] == b[key], key


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**16), st.sampled_from([(64, 256), (128, 512)]),
       st.sampled_from(["azure_2023", "rate_shift", "diurnal"]))
def test_scenario_stream_chunk_size_invariance(seed, sizes, name):
    """The on-device generator emits the same trace whatever the chunk
    size: per-candidate ``fold_in`` randomness plus a host-side float64
    left-to-right arrival clock make the concatenation bitwise equal."""
    from repro.workloads import get_scenario
    from repro.workloads.batch import ScenarioStream

    def collect(csz):
        s = ScenarioStream(get_scenario(name), seed=seed, chunk_size=csz,
                           horizon=40.0)
        rows = []
        while (ch := s.next_chunk()) is not None:
            rows.append(np.stack([ch.t[ch.valid], ch.cls[ch.valid],
                                  ch.P[ch.valid], ch.D[ch.valid]]))
        return np.concatenate(rows, axis=1)

    np.testing.assert_array_equal(collect(sizes[0]), collect(sizes[1]))


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 500), st.sampled_from([{}, {"k_events": 3},
                                             {"fastforward": True}]))
def test_zero_transfer_fleet_is_bitwise_homogeneous(seed, kw):
    """A one-class ``paper-a100`` fleet at ``xfer_scale=0`` must be the
    homogeneous engine, bitwise, on every summary key and on every hot
    path (plain loop, k-event blocks, fast-forward) -- the fleet branch
    only promotes params to per-server arrays and adds an exact ``+0.0``
    transfer term."""
    from repro.core.hetero import FleetSpec

    tt, classes, plan = _mk(3000 + seed, compression=0.3, horizon=20.0)
    pol = gate_and_route(plan)
    fleet = FleetSpec.of([("paper-a100", N)], xfer_scale=0.0)
    a = _jax(tt, classes, pol, 20.0, **kw).run(0)
    cfg = EngineConfig(PRIM, PRICE, n_servers=N, fleet=fleet)
    b = ClusterEngineJAX(classes, pol, cfg, tt, horizon=20.0, **kw).run(0)
    assert set(a) == set(b)
    for key in a:
        assert a[key] == b[key], key


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 500), st.integers(64, 3000),
       st.sampled_from([(0.0, 2.0**17), (2.0**16, 2.0**20),
                        (2.0**17, 2.0**18)]))
def test_transfer_charge_monotone_in_kv_bytes(seed, P, bpair):
    """The KV handoff charge is monotone in KV bytes: a lone request on
    a one-server fleet finishes prefill no earlier as bytes/token grows
    (the charge ``kv_xfer * P`` lands on the finishing chunk, so with a
    strictly larger footprint ``t_first`` strictly increases)."""
    from dataclasses import replace

    from repro.core.hetero import FleetSpec, get_server_class

    base = get_server_class("paper-a100")
    req = [Request(0, 0.25 * (seed % 7), 0, P, 16, patience=1e9)]
    tt = tensorize_trace(req, pad_to=8)
    # rescale the class rate so the plan's occupancy target is ~0.9 --
    # a tiny x* would make the gate reject the lone request outright
    probe = solve_bundled_lp([WorkloadClass("only", P, 16, 1.0, 1e9)],
                             PRIM, PRICE)
    classes = [WorkloadClass("only", P, 16, 0.9 / float(probe.x[0]),
                             patience=1e9)]
    plan = solve_bundled_lp(classes, PRIM, PRICE)

    def t_first(bytes_per_token):
        fleet = FleetSpec.of(
            [(replace(base, kv_bytes_per_token=bytes_per_token), 1)])
        cfg = EngineConfig(PRIM, PRICE, n_servers=1, fleet=fleet)
        eng = ClusterEngineJAX(classes, gate_and_route(plan), cfg, tt,
                               horizon=60.0, drain=True)
        raw = eng.run_raw(0)
        tf = float(np.asarray(raw["t_first"])[0])
        assert np.isfinite(tf)  # the lone request must emit its token
        return tf

    b_lo, b_hi = bpair
    assert t_first(b_lo) < t_first(b_hi)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 500))
def test_conservation_and_capacity_property(seed):
    """On random workloads the fast-forward kernel preserves lifecycle
    invariants: arrivals partition into live+terminal states, decode
    residency stays within slot caps, completions never exceed
    arrivals."""
    tt, classes, plan = _mk(2000 + seed)
    jeng = _jax(tt, classes, gate_and_route(plan), 25.0, fastforward=True)
    raw = {k: np.asarray(v) for k, v in jeng.run_raw(0).items()}
    stl = raw["st"]
    arrived = int((stl != 0).sum())
    assert arrived == int(tt.valid[tt.t <= jeng.h_eff].sum())
    assert np.isin(stl[stl != 0], [1, 2, 3, 4, 5, 6]).all()
    slots = raw["slot_rid"]
    resident = slots[slots >= 0]
    assert len(set(resident)) == resident.size
    assert (stl[resident] == 4).all()
    assert slots.shape == (N, PRIM.batch_cap)
    assert int((stl == 5).sum()) <= arrived
    assert raw["n_events"] >= arrived