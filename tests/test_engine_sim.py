"""Tests for the per-server iteration-level cluster engine."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis; skip where absent
from hypothesis import given, settings, strategies as st

from repro.core.online import OnlineController, OnlineControllerConfig
from repro.core.planning import SLISpec, solve_bundled_lp
from repro.core.policies import (
    ablation_policy,
    baseline_distserve,
    baseline_sarathi,
    baseline_vllm,
    gate_and_route,
    sli_aware_policy,
)
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass
from repro.data.traces import Request, TraceConfig, synth_azure_trace, trace_class_means
from repro.serving.engine_sim import ClusterEngine, EngineConfig

pytestmark = pytest.mark.sim

PRIM = ServicePrimitives()
PRICE = Pricing(0.1, 0.2)


def _mk(seed=42, compression=0.05, horizon=40.0):
    trace = synth_azure_trace(
        TraceConfig(horizon=horizon, base_rate=2.0, compression=compression,
                    seed=seed)
    )
    means = trace_class_means(trace, 2)
    classes = [
        WorkloadClass(n, m[0], m[1], m[2] / 10, patience=3e-4)
        for n, m in zip(("code", "conv"), means)
    ]
    plan = solve_bundled_lp(classes, PRIM, PRICE,
                            sli=SLISpec(pin_zero_decode_queue=True))
    return trace, classes, plan


def _run(trace, classes, pol, n=10, seed=1, horizon=60.0, controller=None,
         drain=False, **kw):
    eng = ClusterEngine(
        classes, pol, EngineConfig(PRIM, PRICE, n_servers=n, seed=seed, **kw),
        controller=controller,
    )
    m = eng.run(trace, horizon=horizon, drain=drain)
    return eng, m


def test_determinism():
    trace, classes, plan = _mk()
    _, m1 = _run(trace, classes, gate_and_route(plan))
    _, m2 = _run(trace, classes, gate_and_route(plan))
    assert m1.revenue == m2.revenue
    assert m1.completions == m2.completions


def test_bundled_revenue_accounting():
    trace, classes, plan = _mk()
    eng, m = _run(trace, classes, gate_and_route(plan))
    per_class = m.per_class_completions
    # each completed request credits exactly w = c_p P + c_d D; since lengths
    # vary per request we check totals against engine-internal tallies instead:
    assert m.revenue > 0
    assert m.completions == sum(per_class.values())


def test_separate_revenue_geq_prefill_part():
    trace, classes, plan = _mk()
    from repro.core.policies import prioritize_and_route
    from repro.core.planning import solve_separate_lp

    sp = solve_separate_lp(classes, PRIM, PRICE)
    eng, m = _run(trace, classes, prioritize_and_route(sp))
    assert m.revenue > 0


def test_ttft_lower_bound():
    """TTFT cannot beat the physical prefill time + one decode iteration."""
    classes = [WorkloadClass("only", 512, 16, 0.1, 0.0)]
    reqs = [Request(0, 0.0, 0, 512, 16)]
    plan = solve_bundled_lp(classes, PRIM, PRICE)
    eng, m = _run(reqs, classes, gate_and_route(plan), n=2, horizon=60.0,
                  drain=True)
    assert m.completions == 1
    n_chunks = int(np.ceil(512 / PRIM.chunk))
    t_prefill = n_chunks * (PRIM.alpha + PRIM.beta * PRIM.chunk)
    assert m.ttft[0] >= t_prefill * 0.99


def test_congested_ordering_matches_paper():
    """Table 2 qualitative claim: gate-and-route out-earns the baselines."""
    trace, classes, plan = _mk(compression=0.02, horizon=60.0)
    _, m_ours = _run(trace, classes, gate_and_route(plan), horizon=90.0)
    _, m_sar = _run(trace, classes, baseline_sarathi(plan), horizon=90.0,
                    sarathi_budget=True)
    _, m_vllm = _run(trace, classes, baseline_vllm(plan), horizon=90.0)
    _, m_dist = _run(trace, classes, baseline_distserve(plan, k=4), horizon=90.0)
    assert m_ours.revenue_rate() > m_sar.revenue_rate()
    assert m_ours.revenue_rate() > m_vllm.revenue_rate()
    assert m_ours.revenue_rate() > m_dist.revenue_rate()


def test_failure_recovery_and_elasticity():
    trace, classes, plan = _mk(compression=0.05)
    ctrl = OnlineController(classes, PRIM, PRICE, n=10)
    events = [(5.0, "fail", 0), (6.0, "fail", 1), (20.0, "recover", 0),
              (8.0, "straggle", 2, 3.0)]
    eng, m = _run(
        trace, classes, gate_and_route(plan), controller=ctrl,
        horizon=60.0,
    )
    # re-run with failures; engine must stay consistent and keep completing
    eng2 = ClusterEngine(
        classes, gate_and_route(plan),
        EngineConfig(PRIM, PRICE, n_servers=10, seed=1), controller=OnlineController(classes, PRIM, PRICE, n=10),
    )
    m2 = eng2.run(trace, horizon=60.0, failure_events=events)
    assert m2.completions > 0
    assert eng2.n_alive == 9  # one server still down
    # conservation: nothing lost
    in_flight = sum(len(s.decodes) + (1 if s.prefill else 0)
                    + len(s.pending_local) for s in eng2.servers)
    queued = sum(len(q) for q in eng2.prefill_q) + len(eng2.decode_buf) + len(
        eng2.decode_buf_solo) + len(eng2.decode_buf_mixed)
    assert m2.completions + m2.abandons + in_flight + queued == m2.arrivals
    # failures cost some throughput vs the clean run
    assert m2.completions <= m.completions


def test_online_controller_replans():
    trace, classes, plan = _mk()
    ctrl = OnlineController(
        classes, PRIM, PRICE, n=10,
        config=OnlineControllerConfig(replan_every=5.0),
    )
    eng, m = _run(trace, classes, gate_and_route(plan), controller=ctrl)
    assert ctrl.replan_count >= 5
    assert ctrl.plan is not None


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(
    ["GG-SP", "FI-WSP", "GI-WSP", "GF-WSP", "FG-SP"]))
def test_conservation_property(seed, which):
    """Pathwise conservation for every ablation policy on random traces."""
    rng = np.random.default_rng(seed)
    classes = [
        WorkloadClass("a", 300, 50, 0.5, 1e-3),
        WorkloadClass("b", 900, 120, 0.5, 1e-3),
    ]
    reqs = []
    t = 0.0
    for rid in range(rng.integers(5, 60)):
        t += rng.exponential(0.3)
        cls = int(rng.integers(2))
        reqs.append(Request(rid, t, cls,
                            int(rng.integers(64, 2048)),
                            int(rng.integers(4, 256))))
    plan = solve_bundled_lp(classes, PRIM, PRICE)
    pol = ablation_policy(plan, which)
    eng = ClusterEngine(classes, pol,
                        EngineConfig(PRIM, PRICE, n_servers=4, seed=seed))
    m = eng.run(reqs, horizon=t + 1.0, drain=True)
    in_flight = sum(len(s.decodes) + (1 if s.prefill else 0)
                    + len(s.pending_local) for s in eng.servers)
    queued = sum(len(q) for q in eng.prefill_q) + len(eng.decode_buf) + len(
        eng.decode_buf_solo) + len(eng.decode_buf_mixed)
    assert m.completions + m.abandons + in_flight + queued == m.arrivals
    # capacity invariants
    for s in eng.servers:
        cap = eng._decode_cap(s)
        assert len(s.decodes) <= cap
        assert s.prefill is None or s.group == "mixed" or pol.partition == "none"


def test_sli_router_routes_to_pools():
    trace, classes, plan = _mk()
    pol = sli_aware_policy(plan, general=True)
    eng, m = _run(trace, classes, pol)
    assert m.completions > 0
