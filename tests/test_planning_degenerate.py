"""Regression tests: simplex anti-cycling + degenerate planner inputs.

No hypothesis dependency -- these must run everywhere (the cycling and
degenerate-input fixes are exactly the paths a stripped container still
exercises through the closed-loop harness).
"""

import numpy as np
import pytest

from repro.core.lp import LPInfeasible, linprog_max
from repro.core.planning import (solve_bundled_lp, solve_plan,
                                 validate_planning_instance)
from repro.core.planning_batch import solve_plan_batch
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass

PRIM = ServicePrimitives()
PRICE = Pricing(c_p=0.1, c_d=0.2)
C0 = WorkloadClass("decode_heavy", 300, 1000, 0.5, 0.1)
C1 = WorkloadClass("prefill_heavy", 3000, 400, 0.5, 0.1)


# ---------------------------------------------------------------------------
# Simplex cycling safety (Bland fallback after a pivot-count threshold)
# ---------------------------------------------------------------------------


def test_beale_cycling_instance_terminates_optimal():
    """Beale's classic example cycles forever under pure Dantzig with
    tie-breaking by lowest index; the Bland fallback must terminate it
    at the true optimum."""
    c = [0.75, -150.0, 0.02, -6.0]
    A_ub = [
        [0.25, -60.0, -1.0 / 25.0, 9.0],
        [0.5, -90.0, -1.0 / 50.0, 3.0],
        [0.0, 0.0, 1.0, 0.0],
    ]
    b_ub = [0.0, 0.0, 1.0]
    res = linprog_max(c, A_ub, b_ub)
    assert res.fun == pytest.approx(0.05, abs=1e-9)
    assert res.x[2] == pytest.approx(1.0, abs=1e-9)


def test_bland_threshold_forces_termination():
    """Even with an immediate Bland switch (threshold 0) the solver must
    reach the same optimum -- the safety valve may cost pivots, never
    correctness."""
    res = linprog_max(
        c=[3, 5], A_ub=[[1, 0], [0, 2], [3, 2]], b_ub=[4, 12, 18],
        bland_after=0)
    assert res.fun == pytest.approx(36.0)
    assert res.x == pytest.approx([2.0, 6.0])


def test_degenerate_planning_lp_still_exact():
    """A degenerate planning instance (two identical classes splitting
    one flow) keeps terminating and agreeing with the offered load."""
    twin = [WorkloadClass("a", 300, 1000, 0.25, 0.1),
            WorkloadClass("b", 300, 1000, 0.25, 0.1)]
    plan = solve_bundled_lp(twin, PRIM, PRICE)
    offered = sum(PRICE.bundled_reward(c) * c.arrival_rate for c in twin)
    assert plan.revenue_rate <= offered + 1e-6
    assert plan.revenue_rate > 0


# ---------------------------------------------------------------------------
# Degenerate planner inputs -> diagnostic LPInfeasible (never a crash)
# ---------------------------------------------------------------------------


def test_empty_class_list_raises_diagnostic():
    with pytest.raises(LPInfeasible, match="empty class list"):
        solve_plan([], PRIM, PRICE)


def test_all_zero_arrival_rates_raise_diagnostic():
    dead = [WorkloadClass("z0", 300, 1000, 0.0, 0.1),
            WorkloadClass("z1", 3000, 400, 0.0, 0.1)]
    with pytest.raises(LPInfeasible, match="arrival rates are zero"):
        solve_plan(dead, PRIM, PRICE)


def test_single_class_zero_rate_raises_but_positive_rate_solves():
    with pytest.raises(LPInfeasible, match="arrival rates are zero"):
        solve_plan([WorkloadClass("z", 300, 1000, 0.0, 0.1)], PRIM, PRICE)
    plan = solve_plan([C0], PRIM, PRICE)  # I = 1 is NOT degenerate
    assert plan.revenue_rate > 0


def test_zero_capacity_raises_diagnostic():
    with pytest.raises(LPInfeasible, match="zero service capacity"):
        solve_plan([C0, C1], PRIM, PRICE, capacity=0.0)


def test_overload_with_zero_patience_reports_pinned_occupancy():
    """theta = 0 pins x_i = lam_i / mu_p_i; an overloaded pin must raise
    with the instance numbers in the message, not a bare residual."""
    hot = [WorkloadClass("hot", 300, 1000, 50.0, 0.0)]
    with pytest.raises(LPInfeasible, match="pinned prefill"):
        solve_plan(hot, PRIM, PRICE)


def test_batch_validation_names_the_offending_instance():
    dead = [WorkloadClass("z", 300, 1000, 0.0, 0.1)]
    with pytest.raises(LPInfeasible, match=r"batch\[1\]"):
        solve_plan_batch([[C0, C1], dead], PRIM, PRICE)


def test_validate_planning_instance_passes_healthy_inputs():
    classes = validate_planning_instance([C0, C1], capacity=2.0)
    assert classes == (C0, C1)


def test_capacity_scales_the_plan():
    base = solve_plan([C0, C1], PRIM, PRICE)
    half = solve_plan([C0, C1], PRIM, PRICE, capacity=0.5)
    assert half.revenue_rate <= base.revenue_rate + 1e-9
    # halving every service rate doubles the prefill occupancy needed for
    # the same served flow, so x_total grows
    assert half.x_total >= base.x_total - 1e-9
