"""Uniformized JAX CTMC engine: statistical equivalence to the Python
event loop, bitwise determinism, conservation laws, and the sweep
evaluator integration.  Also the regression test for the Python
simulator's trajectory-recording clamp."""

import numpy as np
import pytest

from repro.core.ctmc_jax import UniformizedCTMC
from repro.core.planning import SLISpec, solve_bundled_lp
from repro.core.policies import baseline_vllm, gate_and_route
from repro.core.simulator import CTMCSimulator
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass

pytestmark = pytest.mark.sim

CLASSES = [
    WorkloadClass("decode_heavy", 300, 1000, arrival_rate=0.5, patience=0.1),
    WorkloadClass("prefill_heavy", 3000, 400, arrival_rate=0.5, patience=0.1),
]
PRIM = ServicePrimitives()
PRICE = Pricing(0.1, 0.2)


@pytest.fixture(scope="module")
def plan():
    return solve_bundled_lp(CLASSES, PRIM, PRICE,
                            sli=SLISpec(pin_zero_decode_queue=True))


def _half_width(vals):
    return 1.96 * np.std(vals, ddof=1) / np.sqrt(len(vals))


@pytest.mark.parametrize("make_policy", [gate_and_route, baseline_vllm],
                         ids=["gate_and_route", "baseline_vllm"])
def test_statistical_equivalence(plan, make_policy):
    """Revenue rate and average occupancies agree between the engines
    within 2 CI half-widths on the 2-class, n=50 EC.8.5 instance."""
    policy = make_policy(plan)
    n, horizon, warmup, reps = 50, 40.0, 10.0, 12

    sim = CTMCSimulator(CLASSES, PRIM, PRICE, policy, n=n)
    res_py = sim.run_batch(horizon, warmup=warmup,
                           rngs=np.random.SeedSequence(7).spawn(reps))
    jsim = UniformizedCTMC(CLASSES, PRIM, PRICE, policy, n=n,
                           horizon=horizon, warmup=warmup)
    raw = jsim.run_batch_raw(list(range(reps)))
    res_jx = jsim.results_from_raw(raw)

    # the fixed step budget covered the horizon and nothing was clipped
    assert np.all(np.asarray(raw["t"]) == horizon)
    assert np.asarray(raw["clip_steps"]).sum() == 0

    rr_py = np.array([r.revenue_rate_per_server for r in res_py])
    rr_jx = np.array([r.revenue_rate_per_server for r in res_jx])
    tol = 2.0 * (_half_width(rr_py) + _half_width(rr_jx))
    assert abs(rr_py.mean() - rr_jx.mean()) <= tol

    for attr in ("avg_x", "avg_ym", "avg_ys"):
        a_py = np.array([getattr(r, attr) for r in res_py])
        a_jx = np.array([getattr(r, attr) for r in res_jx])
        for i in range(len(CLASSES)):
            tol = 2.0 * (_half_width(a_py[:, i]) + _half_width(a_jx[:, i]))
            assert abs(a_py[:, i].mean() - a_jx[:, i].mean()) <= tol + 1e-4


def test_determinism_same_key_bitwise(plan):
    """Same PRNG seeds => bitwise-identical outputs; different => not."""
    jsim = UniformizedCTMC(CLASSES, PRIM, PRICE, gate_and_route(plan),
                           n=10, horizon=5.0, warmup=1.0)
    a = jsim.run_batch_raw([3, 4])
    b = jsim.run_batch_raw([3, 4])
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    c = jsim.run_batch_raw([3, 5])
    assert float(np.asarray(a["rev"])[1]) != float(np.asarray(c["rev"])[1])
    # single-run API agrees with the batched one
    r0 = jsim.run(3)
    assert r0.revenue == float(np.asarray(a["rev"])[0])


def test_conservation_laws(plan):
    """Pathwise per-class flow conservation in the scanned engine."""
    jsim = UniformizedCTMC(CLASSES, PRIM, PRICE, gate_and_route(plan),
                           n=20, horizon=20.0)
    raw = {k: np.asarray(v) for k, v in jsim.run_raw(11).items()}
    in_system = (raw["qp"] + raw["x"] + raw["qdm"] + raw["qds"]
                 + raw["ym"] + raw["ys"])
    lhs = raw["arrivals"]
    rhs = raw["completions"] + raw["ab_p"] + raw["ab_d"] + in_system
    np.testing.assert_allclose(lhs, rhs, atol=1e-5)
    # capacity invariants at the end state
    assert raw["x"].sum() <= jsim.M + 1e-5
    assert raw["ym"].sum() <= (PRIM.batch_cap - 1) * jsim.M + 1e-5
    assert raw["ys"].sum() <= PRIM.batch_cap * (jsim.n - jsim.M) + 1e-5


def test_ticks_mode_matches_events_mode(plan):
    """Strict Lambda-clock stepping has the same law as the self-loop
    skipped default (coarse check on the mean revenue rate)."""
    kw = dict(n=20, horizon=20.0, warmup=5.0)
    ev = UniformizedCTMC(CLASSES, PRIM, PRICE, gate_and_route(plan), **kw)
    tk = UniformizedCTMC(CLASSES, PRIM, PRICE, gate_and_route(plan),
                         stepping="ticks", **kw)
    assert tk.n_steps > ev.n_steps  # self-loops make the tick budget larger
    r_ev = [r.revenue_rate_per_server for r in ev.run_batch(range(8))]
    r_tk = [r.revenue_rate_per_server for r in tk.run_batch(range(8))]
    tol = 2.0 * (_half_width(r_ev) + _half_width(r_tk))
    assert abs(np.mean(r_ev) - np.mean(r_tk)) <= tol
    raw = tk.run_batch_raw(range(8))
    assert np.all(np.asarray(raw["t"]) == 20.0)
    assert np.asarray(raw["clip_steps"]).sum() == 0


def test_sweep_evaluator_integration(tmp_path):
    """The ctmc_jax evaluator fills the grid with schema-valid cells and
    is deterministic across runs of the same spec."""
    from repro.sweep import SweepSpec, run_sweep
    from repro.sweep.run import default_mix

    spec = SweepSpec(name="t_jax", evaluator="ctmc_jax",
                     policies=("gate_and_route",), n_servers=(10, 20),
                     n_seeds=2, seed=5, mixes=(default_mix("two_class"),),
                     horizon=5.0, warmup=1.0)
    res = run_sweep(spec)
    assert len(res.cells) == spec.n_cells
    m = res.cells[0].metrics
    for key in ("revenue_rate", "gap_pct", "t_end", "clip_steps",
                "n_events", "avg_x/0"):
        assert key in m
    assert m["t_end"] == spec.horizon and m["clip_steps"] == 0
    assert run_sweep(spec).fingerprint() == res.fingerprint()
    res.save(tmp_path / "t_jax_sweep.json")  # exercises validate_payload


def test_record_every_rejected():
    from repro.sweep import SweepSpec, run_sweep
    from repro.sweep.run import default_mix

    spec = SweepSpec(name="t_rec", evaluator="ctmc_jax",
                     policies=("gate_and_route",), n_servers=(10,),
                     n_seeds=1, mixes=(default_mix("two_class"),),
                     horizon=2.0, record_every=0.5)
    with pytest.raises(ValueError, match="trajector"):
        run_sweep(spec)


def test_python_trajectory_clamped_to_horizon(plan):
    """Regression: with record_every not dividing the horizon, samples
    must stay on the record grid (no drift) and the trajectory must
    close at exactly the horizon."""
    horizon, rec = 5.0, 0.7
    sim = CTMCSimulator(CLASSES, PRIM, PRICE, gate_and_route(plan), n=10,
                        seed=13, record_every=rec)
    res = sim.run(horizon)
    t = res.trajectory["t"]
    assert t.size >= 2
    assert np.all(np.diff(t) > 0)
    assert t.max() <= horizon
    assert t[-1] == horizon
    # one in-loop sample per crossed grid cell: no comb drift
    cells = np.floor(t[:-1] / rec).astype(int)
    assert np.unique(cells).size == cells.size
    assert t.size <= int(np.floor(horizon / rec)) + 2
