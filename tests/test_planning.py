"""Planning LP tests: paper Eq. (40)/(42)/(49) structure + Proposition 1."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis; skip where absent
from hypothesis import given, settings, strategies as st

from repro.core.planning import (
    SLISpec,
    solve_bundled_lp,
    solve_separate_lp,
    tpot_of_plan,
)
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass, rate_arrays

# The paper's EC.8.5 synthetic instance.
C0 = WorkloadClass("decode_heavy", prompt_len=300, decode_len=1000,
                   arrival_rate=0.5, patience=0.1)
C1 = WorkloadClass("prefill_heavy", prompt_len=3000, decode_len=400,
                   arrival_rate=0.5, patience=0.1)
PRIM = ServicePrimitives()
PRICE = Pricing(c_p=0.1, c_d=0.2)


def _check_feasible(plan, tol=1e-7):
    arr = rate_arrays(plan.classes, plan.prim)
    B = plan.prim.batch_cap
    assert plan.x.sum() <= 1 + tol
    assert plan.ym.sum() <= (B - 1) * plan.x.sum() + tol
    assert plan.ys.sum() <= B * (1 - plan.x.sum()) + tol
    np.testing.assert_allclose(
        arr["mu_p"] * plan.x + arr["theta"] * plan.qp, arr["lam"], atol=1e-6
    )
    np.testing.assert_allclose(
        arr["mu_p"] * plan.x - arr["theta"] * plan.qd,
        arr["mu_m"] * plan.ym + arr["mu_s"] * plan.ys,
        atol=1e-6,
    )
    assert np.all(plan.x >= -tol) and np.all(plan.qp >= -tol)
    assert np.all(plan.ym >= -tol) and np.all(plan.ys >= -tol)


def test_bundled_lp_solves_and_is_feasible():
    plan = solve_bundled_lp([C0, C1], PRIM, PRICE)
    _check_feasible(plan)
    assert plan.revenue_rate > 0
    # Underloaded instance: everything is served, revenue equals full offered
    # reward iff queues are empty.
    w = np.array([PRICE.bundled_reward(c) for c in (C0, C1)])
    offered = float((w * np.array([0.5, 0.5])).sum())
    assert plan.revenue_rate <= offered + 1e-6


def test_proposition1_decode_buffer_elimination():
    """gamma*tau >= (B-1)/B  =>  pinning q_d = 0 costs nothing (Prop 1)."""
    assert PRIM.solo_efficiency_ok
    base = solve_bundled_lp([C0, C1], PRIM, PRICE)
    pinned = solve_bundled_lp([C0, C1], PRIM, PRICE,
                              sli=SLISpec(pin_zero_decode_queue=True))
    assert pinned.revenue_rate == pytest.approx(base.revenue_rate, rel=1e-6)
    assert np.all(np.abs(pinned.qd) < 1e-8)


def test_separate_lp_objective_structure():
    plan = solve_separate_lp([C0, C1], PRIM, PRICE)
    _check_feasible(plan)
    val = (
        PRICE.c_p * PRIM.chunk / PRIM.tau_mix * plan.x.sum()
        + PRICE.c_d / PRIM.tau_mix * plan.ym.sum()
        + PRICE.c_d * PRIM.gamma * plan.ys.sum()
    )
    assert val == pytest.approx(plan.revenue_rate, rel=1e-9)
    # Separate charging earns at least the bundled completion revenue rate at
    # its own optimum evaluated on the same objective.
    bundled = solve_separate_lp([C0, C1], PRIM, PRICE)
    assert plan.revenue_rate >= bundled.revenue_rate - 1e-9


def test_tpot_cap_binds():
    eta = 0.024  # between 1/gamma = 0.0089*... and tau
    plan = solve_bundled_lp([C0, C1], PRIM, PRICE, sli=SLISpec(tpot_cap=eta))
    assert tpot_of_plan(plan) <= eta + 1e-9
    loose = solve_bundled_lp([C0, C1], PRIM, PRICE)
    assert plan.revenue_rate <= loose.revenue_rate + 1e-9


def test_prefill_fairness_cap():
    eta = 0.01
    plan = solve_bundled_lp([C0, C1], PRIM, PRICE,
                            sli=SLISpec(prefill_fairness_cap=eta))
    gaps = plan.x[:, None] - plan.x[None, :]
    assert gaps.max() <= eta + 1e-9


def test_fairness_penalty_reduces_gap():
    base = solve_bundled_lp([C0, C1], PRIM, PRICE)
    pen = solve_bundled_lp(
        [C0, C1], PRIM, PRICE, sli=SLISpec(prefill_fairness_penalty=1e4)
    )
    gap = lambda p: float(np.max(p.x[:, None] - p.x[None, :]))
    assert gap(pen) <= gap(base) + 1e-9


def test_mixed_servers_partition():
    plan = solve_bundled_lp([C0, C1], PRIM, PRICE)
    n = 10
    m = plan.mixed_servers(n)
    assert 0 <= m <= n
    assert m == int(np.ceil(n * plan.x.sum() - 1e-12))


@st.composite
def _random_instance(draw):
    I = draw(st.integers(1, 4))
    classes = []
    for i in range(I):
        P = draw(st.floats(50, 4000))
        D = draw(st.floats(20, 2000))
        lam = draw(st.floats(0.01, 1.5))
        th = draw(st.floats(0.01, 0.5))
        classes.append(WorkloadClass(f"c{i}", P, D, lam, th))
    B = draw(st.integers(4, 32))
    return classes, ServicePrimitives(batch_cap=B)


@settings(max_examples=30, deadline=None)
@given(_random_instance())
def test_lp_always_feasible_and_consistent(inst):
    classes, prim = inst
    plan = solve_bundled_lp(classes, prim, PRICE)
    _check_feasible(plan)
    # Proposition 1 under the calibrated-regime condition.
    if prim.solo_efficiency_ok:
        pinned = solve_bundled_lp(classes, prim, PRICE,
                                  sli=SLISpec(pin_zero_decode_queue=True))
        assert pinned.revenue_rate == pytest.approx(
            plan.revenue_rate, rel=1e-6, abs=1e-9
        )
