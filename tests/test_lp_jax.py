"""Deterministic oracle-agreement tests for the batched LP/planning stack.

``repro.core.lp_jax`` (fixed-iteration interior point) and
``repro.core.planning_batch`` (stacked Eq. 40/42 assembly) are held to
the serial simplex oracle (``linprog_max`` / ``solve_plan``) on the full
planning test corpus, within the tolerance documented in
``docs/PLANNING.md``: relative 1e-6 on objectives, same-scale primal
feasibility.  Vertices are NOT compared -- degenerate LPs have alternate
optima and the IPM returns a face-interior point.

Hypothesis-based property tests live in
``tests/test_lp_jax_properties.py`` (whole-module importorskip) so this
module still runs where hypothesis is absent; the sweep-evaluator
integration test is ``sim``-marked with the other jax-engine tests.
"""

import numpy as np
import pytest

from repro.core.lp import linprog_max
from repro.core.lp_jax import linprog_max_jax, solve_lp_batch
from repro.core.planning import SLISpec, solve_bundled_lp, solve_plan
from repro.core.planning_batch import solve_plan_batch, solve_plan_jax
from repro.core.types import (Pricing, ServicePrimitives, WorkloadClass,
                              rate_arrays)

REL_TOL = 1e-6  # documented objective tolerance vs the oracle

# the EC.8.5 synthetic instance anchoring the planning corpus
C0 = WorkloadClass("decode_heavy", 300, 1000, 0.5, 0.1)
C1 = WorkloadClass("prefill_heavy", 3000, 400, 0.5, 0.1)
PRIM = ServicePrimitives()
PRICE = Pricing(c_p=0.1, c_d=0.2)

# (label, solve_plan kwargs): every SLI structure the planner supports
PLAN_CORPUS = [
    ("bundled", dict(objective="bundled")),
    ("separate", dict(objective="separate")),
    ("pin_qd", dict(sli=SLISpec(pin_zero_decode_queue=True))),
    ("tpot_cap", dict(sli=SLISpec(tpot_cap=0.024))),
    ("prefill_cap", dict(sli=SLISpec(prefill_fairness_cap=0.01))),
    ("decode_cap", dict(sli=SLISpec(decode_fairness_cap=0.5))),
    ("prefill_pen", dict(sli=SLISpec(prefill_fairness_penalty=1e4))),
    ("both_pen", dict(sli=SLISpec(prefill_fairness_penalty=100.0,
                                  decode_fairness_penalty=10.0))),
]


def rel_err(a, b):
    return abs(a - b) / (1.0 + abs(a))


def check_plan_feasible(plan, tol=1e-6):
    arr = rate_arrays(plan.classes, plan.prim)
    B = plan.prim.batch_cap
    assert plan.x.sum() <= 1 + tol
    assert plan.ym.sum() <= (B - 1) * plan.x.sum() + tol
    assert plan.ys.sum() <= B * (1 - plan.x.sum()) + tol
    np.testing.assert_allclose(
        arr["mu_p"] * plan.x + arr["theta"] * plan.qp, arr["lam"],
        atol=1e-5)
    np.testing.assert_allclose(
        arr["mu_p"] * plan.x - arr["theta"] * plan.qd,
        arr["mu_m"] * plan.ym + arr["mu_s"] * plan.ys, atol=1e-5)
    for v in (plan.x, plan.ym, plan.ys, plan.qp, plan.qd):
        assert np.all(v >= -tol)


def test_textbook_lp_matches_oracle():
    # max 3x + 5y s.t. x <= 4, 2y <= 12, 3x + 2y <= 18 -> (2, 6), obj 36
    res = linprog_max_jax(c=[3, 5], A_ub=[[1, 0], [0, 2], [3, 2]],
                          b_ub=[4, 12, 18])
    assert bool(res.converged)
    assert res.fun == pytest.approx(36.0, abs=1e-6)
    assert res.x == pytest.approx([2.0, 6.0], abs=1e-6)
    assert res.dual_ub == pytest.approx([0.0, 1.5, 1.0], abs=1e-6)


def test_equality_lp_matches_oracle():
    res = linprog_max_jax(c=[1, 2], A_eq=[[1, 1]], b_eq=[1])
    assert bool(res.converged)
    assert res.fun == pytest.approx(2.0, abs=1e-6)
    assert res.dual_eq == pytest.approx([2.0], abs=1e-6)


def test_redundant_equality_rows_still_converge():
    res = linprog_max_jax(c=[1, 1], A_ub=[[1, 0]], b_ub=[0.25],
                          A_eq=[[1, 1], [2, 2]], b_eq=[1, 2])
    assert bool(res.converged)
    assert res.fun == pytest.approx(1.0, abs=1e-6)


def test_batch_values_match_per_instance_solves():
    rng = np.random.default_rng(7)
    n, m, S = 4, 3, 8
    cs, As, bs = [], [], []
    for _ in range(S):
        cs.append(rng.normal(size=n))
        As.append(np.vstack([rng.normal(size=(m, n)), np.ones((1, n))]))
        bs.append(np.concatenate([rng.uniform(0.5, 2.0, size=m), [5.0]]))
    res = solve_lp_batch(np.stack(cs), np.stack(As), np.stack(bs))
    assert res.converged.all()
    for k in range(S):
        ref = linprog_max(cs[k], As[k], bs[k])
        assert rel_err(ref.fun, res.fun[k]) < REL_TOL
        # strong duality holds batched too
        assert rel_err(res.fun[k],
                       float(bs[k] @ res.dual_ub[k])) < 1e-5


@pytest.mark.parametrize("label,kw", PLAN_CORPUS)
def test_planning_corpus_agrees_with_oracle(label, kw):
    oracle = solve_plan([C0, C1], PRIM, PRICE, **kw)
    pb = solve_plan_batch([(C0, C1)], PRIM, PRICE, **kw)
    assert bool(pb.converged[0]), (label, pb.primal_res, pb.dual_res)
    sol = pb.solution(0)
    assert rel_err(oracle.revenue_rate, sol.revenue_rate) < REL_TOL
    assert rel_err(oracle.sli_value, sol.sli_value) < 1e-4
    check_plan_feasible(sol)


def test_mixed_class_counts_pad_and_agree():
    inst1 = (C0,)
    inst3 = (C0, C1, WorkloadClass("mid", 800, 600, 0.3, 0.05))
    pb = solve_plan_batch([(C0, C1), inst3, inst1], PRIM, PRICE)
    assert pb.converged.all()
    for k, inst in enumerate([(C0, C1), inst3, inst1]):
        oracle = solve_bundled_lp(inst, PRIM, PRICE)
        sol = pb.solution(k)
        assert len(sol.x) == len(inst)  # padding sliced off
        assert rel_err(oracle.revenue_rate, sol.revenue_rate) < REL_TOL
        check_plan_feasible(sol)


def test_padded_instances_with_fairness_caps_agree():
    """Regression: pairwise fairness rows must never anchor on the pad
    filler class (x_pad ~ 0 would turn x_i - x_pad <= cap into an
    absolute cap the unpadded LP does not have)."""
    sli = SLISpec(prefill_fairness_cap=0.05)
    inst3 = (C0, C1, WorkloadClass("mid", 800, 600, 0.3, 0.05))
    pb = solve_plan_batch([(C0, C1), inst3], PRIM, PRICE, sli=sli)
    assert pb.converged.all()
    for k, inst in enumerate([(C0, C1), inst3]):
        oracle = solve_bundled_lp(inst, PRIM, PRICE, sli=sli)
        assert rel_err(oracle.revenue_rate, pb.revenue_rate[k]) < REL_TOL
    # penalty aux columns must not see the pad either
    sli_pen = SLISpec(prefill_fairness_penalty=100.0)
    pb = solve_plan_batch([(C0, C1), inst3], PRIM, PRICE, sli=sli_pen)
    assert pb.converged.all()
    for k, inst in enumerate([(C0, C1), inst3]):
        oracle = solve_bundled_lp(inst, PRIM, PRICE, sli=sli_pen)
        assert rel_err(oracle.revenue_rate, pb.revenue_rate[k]) < REL_TOL


def test_solve_plan_jax_raises_on_infeasible_instance():
    """Regression: the jitted path must not publish a garbage plan where
    the simplex oracle raises (converged flag funnels into LPInfeasible)."""
    from repro.core.lp import LPInfeasible

    hot = (WorkloadClass("hot", 300, 1000, 50.0, 0.0),)
    with pytest.raises(LPInfeasible):
        solve_plan((list(hot)), PRIM, PRICE)  # oracle behaviour
    with pytest.raises(LPInfeasible, match="did not converge"):
        solve_plan_jax(hot, PRIM, PRICE)


def test_prewarm_plans_covers_gate_and_route_separate():
    """Regression: the separate-plan token must prewarm the 'separate'
    kind, or batch_plans sweeps fall back to the serial simplex."""
    from repro.sweep.evaluators import MixContext, prewarm_plans
    from repro.sweep.run import default_mix
    from repro.sweep.spec import SweepSpec

    mix = default_mix("two_class")
    ctx = MixContext(mix, SweepSpec(mixes=(mix,)))
    prewarm_plans([ctx], ["gate_and_route_separate"])
    assert "separate" in ctx._plans
    oracle = solve_plan(ctx.classes, ctx.prim, ctx.pricing,
                        objective="separate")
    assert rel_err(oracle.revenue_rate,
                   ctx._plans["separate"].revenue_rate) < REL_TOL


def test_batched_sli_caps_trace_the_frontier():
    caps = np.linspace(1e-4, 2.0, 7)
    pb = solve_plan_batch([(C0, C1)] * len(caps), PRIM, PRICE,
                          sli=SLISpec(decode_fairness_cap=caps))
    assert pb.converged.all()
    for k, cap in enumerate(caps):
        oracle = solve_bundled_lp(
            (C0, C1), PRIM, PRICE, sli=SLISpec(decode_fairness_cap=float(cap)))
        assert rel_err(oracle.revenue_rate, pb.revenue_rate[k]) < REL_TOL
    # revenue is nondecreasing in the cap (weaker constraint)
    assert np.all(np.diff(pb.revenue_rate) >= -1e-6)


def test_capacity_and_pricing_axes():
    pricings = [Pricing(0.1, 0.2), Pricing(0.2, 0.1), Pricing(0.05, 0.4)]
    caps = [1.0, 0.5, 2.0]
    pb = solve_plan_batch([(C0, C1)] * 3, PRIM, pricings=pricings,
                          capacity=caps)
    assert pb.converged.all()
    for k in range(3):
        oracle = solve_plan((C0, C1), PRIM, pricings[k], capacity=caps[k])
        assert rel_err(oracle.revenue_rate, pb.revenue_rate[k]) < REL_TOL


def test_solve_plan_jax_is_plan_solution_compatible():
    sol = solve_plan_jax((C0, C1), PRIM, PRICE)
    oracle = solve_bundled_lp((C0, C1), PRIM, PRICE)
    assert rel_err(oracle.revenue_rate, sol.revenue_rate) < REL_TOL
    assert sol.mixed_servers(10) == oracle.mixed_servers(10)
    probs = sol.solo_probs()
    assert probs.shape == (2,) and np.all((0 <= probs) & (probs <= 1))


def test_online_controller_lp_jax_solver_matches_simplex():
    from repro.core.online import OnlineController, OnlineControllerConfig

    plans = {}
    for solver in ("simplex", "lp_jax"):
        rng = np.random.default_rng(3)
        ctl = OnlineController(
            (C0, C1), PRIM, PRICE, n=10,
            config=OnlineControllerConfig(solver=solver))
        for t in np.sort(rng.uniform(0, 20, 300)):
            ctl.observe_arrival(float(t), int(rng.integers(0, 2)))
        plans[solver] = ctl.replan(20.0)
    a, b = plans["simplex"], plans["lp_jax"]
    assert rel_err(a.revenue_rate, b.revenue_rate) < REL_TOL
    np.testing.assert_allclose(a.x, b.x, atol=1e-5)
    assert a.mixed_servers(10) == b.mixed_servers(10)


def test_online_controller_rejects_unknown_solver():
    from repro.core.online import OnlineControllerConfig

    with pytest.raises(ValueError, match="solver"):
        OnlineControllerConfig(solver="gurobi")


def test_replan_controllers_batch_matches_serial_replans():
    import copy

    from repro.core.online import (OnlineController, OnlineControllerConfig,
                                   replan_controllers_batch)

    rng = np.random.default_rng(11)
    ctls = []
    for k in range(3):
        ctl = OnlineController((C0, C1), PRIM, PRICE, n=8,
                               config=OnlineControllerConfig())
        for t in np.sort(rng.uniform(0, 15, 80 + 60 * k)):
            ctl.observe_arrival(float(t), int(rng.integers(0, 2)))
        ctls.append(ctl)
    refs = [copy.deepcopy(c) for c in ctls]
    plans = replan_controllers_batch(ctls, 15.0)
    assert len(plans) == 3
    for ctl, ref in zip(ctls, refs):
        ref.replan(15.0)
        assert ctl.replan_count == 1
        assert ctl._next_replan >= 15.0 + ctl.cfg.replan_every
        assert rel_err(ref.plan.revenue_rate,
                       ctl.plan.revenue_rate) < REL_TOL


def test_gate_and_route_separate_token_resolves():
    """bench_optimality_gap's separate-scheme policy: the plan-tracking
    occupancy gate built from the Eq. 42 plan, charged separately."""
    from repro.sweep.evaluators import MixContext, resolve_policy
    from repro.sweep.run import default_mix
    from repro.sweep.spec import SweepSpec

    mix = default_mix("two_class")
    ctx = MixContext(mix, SweepSpec(mixes=(mix,)))
    pol = resolve_policy("gate_and_route_separate", ctx, n=10)
    assert pol.charging == "separate"
    assert pol.plan.objective == "separate"
    np.testing.assert_allclose(pol.gate.x_star, ctx.plan("separate").x)


@pytest.mark.sim
def test_lp_jax_sweep_evaluator_matches_lp_evaluator():
    from repro.sweep import SweepSpec, run_sweep
    from repro.sweep.run import default_mix

    mixes = (default_mix("two_class"),)
    tokens = ("lp", "lp_separate", "lp_sli")
    ref = run_sweep(SweepSpec(name="ref", evaluator="lp", policies=tokens,
                              n_servers=(10,), n_seeds=2, mixes=mixes))
    got = run_sweep(SweepSpec(name="got", evaluator="lp_jax",
                              policies=tokens, n_servers=(10,), n_seeds=2,
                              mixes=mixes))
    assert len(got.cells) == len(ref.cells) == len(tokens) * 2
    for ca, cb in zip(ref.cells, got.cells):
        assert (ca.mix, ca.policy, ca.n, ca.seed) == (
            cb.mix, cb.policy, cb.n, cb.seed)
        assert cb.metrics["lp_converged"] == 1.0
        assert cb.metrics["lp_gap"] < 1e-8
        for key in ("revenue", "tpot", "x_total"):
            assert rel_err(ca.metrics[key], cb.metrics[key]) < 1e-5, key
    # artifact round-trips through the published schema
    from repro.sweep.spec import validate_payload

    validate_payload(got.to_payload())
