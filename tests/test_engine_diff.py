"""Differential harness locking down the engine hot path.

Three families of evidence that the multi-event/fast-forward kernels and
the streamed replay are the *same simulator* as the one-event-per-step
scan (and, transitively, the Python event loop):

1. **Bitwise** -- ``k_events > 1`` replays every raw carry array
   identically to ``k_events = 1`` (only ``n_loop``, which counts scan
   steps, may differ: k events retire per step by construction).
2. **Statistical** -- ``fastforward=True`` and the streamed engine agree
   with their one-event twins *exactly* on discrete outcomes
   (arrivals/completions) per trace, and with the Python
   :class:`~repro.serving.engine_sim.ClusterEngine` oracle within CI
   half-widths across independent traces.  Equivalence is measured
   ACROSS TRACE SEEDS: on a fixed trace the deterministic policies are
   PRNG-invariant, so per-seed spread degenerates to zero and any
   comparison there is vacuous.
3. **Metamorphic** (hypothesis) -- summaries are k-invariant, streamed
   scenario traces are chunk-size-invariant, and conservation/capacity
   laws hold under randomly drawn workloads.  These live in
   ``test_engine_diff_properties.py`` (module-scope ``importorskip``
   convention: they skip wholesale where hypothesis is absent, and this
   module's deterministic families must not skip with them).

Plus the registry regression riding along (every workload scenario
replays its CI-size trace with ``budget_exhausted == 0``).
"""

import re

import numpy as np
import pytest

from repro.core.planning import SLISpec, solve_bundled_lp
from repro.core.policies import (baseline_distserve, baseline_sarathi,
                                 baseline_vllm, gate_and_route,
                                 sli_aware_policy)
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass
from repro.data.traces import (TraceConfig, TraceValidationError,
                               synth_azure_trace, tensorize_trace,
                               trace_class_means)
from repro.serving.engine_jax import ClusterEngineJAX
from repro.serving.engine_sim import ClusterEngine, EngineConfig
from repro.serving.engine_stream import StreamingEngineJAX, TraceChunkSource

pytestmark = pytest.mark.sim

PRIM = ServicePrimitives()
PRICE = Pricing(0.1, 0.2)
N = 8
HORIZON = 25.0
PAD = 512  # shared padded trace shape => one jit cache entry per leg

POLICIES = {
    "gate_and_route": gate_and_route,
    "vllm": baseline_vllm,
    "sarathi": baseline_sarathi,
    "distserve": lambda plan: baseline_distserve(plan, 3),
    "sli": sli_aware_policy,
}

_MK_CACHE = {}


def _mk(seed=42, compression=0.2, horizon=HORIZON):
    """(padded TraceTensors, raw trace, classes, plan) for one workload."""
    key = (seed, compression, horizon)
    if key not in _MK_CACHE:
        trace = synth_azure_trace(TraceConfig(
            horizon=horizon, base_rate=2.0, compression=compression,
            seed=seed))
        assert len(trace) <= PAD
        means = trace_class_means(trace, 2)
        classes = [WorkloadClass(nm, m[0], m[1], m[2] / N, patience=3e-4)
                   for nm, m in zip(("code", "conv"), means)]
        plan = solve_bundled_lp(classes, PRIM, PRICE,
                                sli=SLISpec(pin_zero_decode_queue=True))
        _MK_CACHE[key] = (tensorize_trace(trace, pad_to=PAD), trace,
                          classes, plan)
    return _MK_CACHE[key]


def _jax(tt, classes, pol, horizon=HORIZON, **kw):
    return ClusterEngineJAX(classes, pol,
                            EngineConfig(PRIM, PRICE, n_servers=N),
                            tt, horizon=horizon, **kw)


def _half_width(vals):
    return 1.96 * np.std(vals, ddof=1) / np.sqrt(len(vals))


def _ci_close(a, b, label, rel_floor=0.0):
    """CI-half-width agreement with an optional relative floor: when the
    per-seed spread degenerates (a randomized policy whose coin flips
    happen not to matter on a trace), the CI collapses below float32-vs-
    float64 arithmetic drift and the comparison needs a drift-scale
    floor to be meaningful."""
    a, b = np.asarray(a, float), np.asarray(b, float)
    tol = (2.0 * (_half_width(a) + _half_width(b)) + 1e-9
           + rel_floor * max(abs(a.mean()), abs(b.mean())))
    assert abs(a.mean() - b.mean()) <= tol, (
        f"{label}: |{a.mean()} - {b.mean()}| > {tol}")


# ---------------------------------------------------------------- bitwise

@pytest.mark.parametrize("name,k", [
    ("gate_and_route", 2), ("vllm", 2), ("sli", 2), ("distserve", 3),
], ids=lambda v: str(v))
def test_k_event_blocks_bitwise(name, k):
    """k-event blocks replay the exact single-event trajectory: every
    raw output array is bitwise identical except the scan-step counter
    ``n_loop`` (k events per step by construction)."""
    tt, _, classes, plan = _mk(seed=9, compression=0.3, horizon=20.0)
    pol = POLICIES[name](plan)
    a = _jax(tt, classes, pol, horizon=20.0).run_batch_raw([0, 1])
    b = _jax(tt, classes, pol, horizon=20.0,
             k_events=k).run_batch_raw([0, 1])
    for key in set(a) & set(b):
        if key == "n_loop":
            assert (np.asarray(a[key]) >= np.asarray(b[key])).all()
            continue
        np.testing.assert_array_equal(np.asarray(a[key]),
                                      np.asarray(b[key]), err_msg=key)
    # the two resident-token representations describe the same state:
    # dense (n, B) per-slot counters vs the (R,) per-request array
    if "tout" in a and "slot_tout" in b:
        slots = np.asarray(a["slot_rid"])
        occ = slots >= 0
        tout = np.take_along_axis(
            np.asarray(a["tout"]), np.where(occ, slots, 0).reshape(
                slots.shape[0], -1), axis=1).reshape(slots.shape)
        np.testing.assert_array_equal(np.where(occ, tout, 0.0),
                                      np.where(occ, np.asarray(
                                          b["slot_tout"]), 0.0))


# ------------------------------------------------------------ statistical

@pytest.mark.parametrize("name", ["gate_and_route", "vllm", "distserve"])
def test_fastforward_vs_single_event(name):
    """Fast-forward replays the same arrivals per trace, the same
    completions up to near-tie event-order flips (closed-form partial
    sums vs chained float32 adds drift ~1e-4; on a saturated
    no-admission-gate policy one flipped tie reorders a whole arrival
    burst, moving a few completions across the horizon), and is
    statistically indistinguishable on continuous metrics across
    traces."""
    rev, ttft = [], []
    for s in range(6):
        tt, _, classes, plan = _mk(seed=200 + s)
        pol = POLICIES[name](plan)
        m1 = _jax(tt, classes, pol).run(0)
        mf = _jax(tt, classes, pol, fastforward=True).run(0)
        assert mf["budget_exhausted"] == 0.0
        assert mf["arrivals"] == m1["arrivals"]
        assert mf["completions"] == pytest.approx(m1["completions"],
                                                  rel=0.02, abs=3)
        rev.append((m1["revenue_rate"], mf["revenue_rate"]))
        ttft.append((m1["ttft_mean"], mf["ttft_mean"]))
    for pairs, label in ((rev, "revenue_rate"), (ttft, "ttft_mean")):
        _ci_close([p[0] for p in pairs], [p[1] for p in pairs], label)


def test_fastforward_requires_deterministic_router():
    """The closed-form window needs a deterministic global-buffer
    router; randomized / immediate routers must be rejected loudly."""
    tt, _, classes, plan = _mk(seed=9, compression=0.3, horizon=20.0)
    for name in ("sli", "sarathi"):
        with pytest.raises(ValueError, match="fastforward"):
            _jax(tt, classes, POLICIES[name](plan), horizon=20.0,
                 fastforward=True)


@pytest.mark.parametrize("name,pykw,jkw", [
    ("gate_and_route", {}, dict(fastforward=True)),
    ("vllm", {}, dict(fastforward=True)),
    ("distserve", {}, dict(fastforward=True)),
    ("sarathi", dict(sarathi_budget=True), dict(k_events=2)),
], ids=["gate_and_route", "vllm", "distserve", "sarathi"])
def test_python_oracle_statistical(name, pykw, jkw):
    """Hot-path engines match the Python event loop within CI
    half-widths across independent traces (the oracle the pre-hot-path
    engine was originally validated against)."""
    rev, comp = [], []
    for s in range(5):
        tt, trace, classes, plan = _mk(seed=300 + s)
        pol = POLICIES[name](plan)
        m_py = ClusterEngine(classes, pol,
                             EngineConfig(PRIM, PRICE, n_servers=N,
                                          seed=1, **pykw)
                             ).run(trace, horizon=HORIZON).summary()
        m_jx = _jax(tt, classes, pol, **jkw).run(0)
        assert m_jx["budget_exhausted"] == 0.0
        assert m_py["arrivals"] == m_jx["arrivals"]
        assert m_jx["completions"] == pytest.approx(m_py["completions"],
                                                    rel=0.06, abs=3)
        rev.append((m_py["revenue_rate"], m_jx["revenue_rate"]))
        comp.append((m_py["completions"], m_jx["completions"]))
    for pairs, label in ((rev, "revenue_rate"), (comp, "completions")):
        _ci_close([p[0] for p in pairs], [p[1] for p in pairs], label)


def test_python_oracle_statistical_sli():
    """The randomized router compares across replications (same trace,
    different PRNG streams -- here seeds genuinely matter) with the
    k-event block engine on the jax side."""
    tt, trace, classes, plan = _mk(seed=11)
    pol = POLICIES["sli"](plan)
    reps = 8
    r_py = [ClusterEngine(classes, pol,
                          EngineConfig(PRIM, PRICE, n_servers=N, seed=s)
                          ).run(trace, horizon=HORIZON).revenue_rate()
            for s in range(reps)]
    jeng = _jax(tt, classes, pol, k_events=2)
    r_jx = [m["revenue_rate"] for m in jeng.run_batch(range(reps))]
    _ci_close(r_py, r_jx, "revenue_rate", rel_floor=1e-5)


# -------------------------------------------------------------- streaming

@pytest.mark.parametrize("name", ["gate_and_route", "vllm"])
@pytest.mark.parametrize("chunk", [64, 160])
def test_stream_matches_batch(name, chunk):
    """A chunk-fed streamed replay reproduces the host-padded drain-mode
    replay of the same trace: same arrivals/completions, float-noise
    agreement on the continuous metrics."""
    _, trace, classes, plan = _mk(seed=7, compression=0.3, horizon=30.0)
    pol = POLICIES[name](plan)
    ref = ClusterEngineJAX(classes, pol,
                           EngineConfig(PRIM, PRICE, n_servers=N),
                           tensorize_trace(_strip_patience(trace)),
                           horizon=30.0, drain=True,
                           fastforward=True).run(0)
    se = StreamingEngineJAX(classes, pol,
                            EngineConfig(PRIM, PRICE, n_servers=N),
                            horizon=30.0, window=PAD)
    s = se.run_stream(TraceChunkSource(_strip_patience(trace),
                                       chunk_size=chunk), seed=0)
    assert s["arrivals"] == ref["arrivals"]
    assert s["completions"] == ref["completions"]
    assert s["abandons"] == ref["abandons"]
    assert s["budget_exhausted"] == 0.0
    assert s["revenue_rate"] == pytest.approx(ref["revenue_rate"],
                                              rel=1e-5)
    assert s["ttft_mean"] == pytest.approx(ref["ttft_mean"], rel=1e-4)
    assert s["n_segments"] >= 2  # the test actually crossed a seam


def _strip_patience(trace):
    return [type(r)(rid=r.rid, t_arrival=r.t_arrival, cls=r.cls,
                    prompt_len=r.prompt_len, decode_len=r.decode_len,
                    patience=float("inf")) for r in trace]


def test_stream_source_validation():
    """Seam and shape defects fail loudly, never silently reorder."""
    _, trace, classes, plan = _mk(seed=7, compression=0.3, horizon=30.0)
    trace = _strip_patience(trace)
    from repro.data.traces import chunk_trace
    chunks = chunk_trace(trace, 64)
    se = StreamingEngineJAX(classes, POLICIES["vllm"](plan),
                            EngineConfig(PRIM, PRICE, n_servers=N),
                            horizon=30.0, window=PAD)
    with pytest.raises(TraceValidationError, match="order"):
        se.run_stream(iter_chunks(chunks[::-1]))
    with pytest.raises(TraceValidationError, match="shape"):
        TraceChunkSource([chunks[0], tensorize_trace(trace, pad_to=256)])
    # randomized routers cannot stream (no deterministic compaction)
    with pytest.raises(ValueError, match="router"):
        StreamingEngineJAX(classes, POLICIES["sli"](plan),
                           EngineConfig(PRIM, PRICE, n_servers=N),
                           horizon=30.0, window=PAD)
    # deadlines are not modelled by the compactor
    finite = [type(r)(rid=r.rid, t_arrival=r.t_arrival, cls=r.cls,
                      prompt_len=r.prompt_len, decode_len=r.decode_len,
                      patience=0.5) for r in trace]
    with pytest.raises(ValueError, match="patience"):
        se.run_stream(TraceChunkSource(finite, chunk_size=64))


class iter_chunks:
    def __init__(self, chunks):
        self._it = iter(chunks)

    def next_chunk(self):
        return next(self._it, None)


def test_stream_window_overflow_is_loud():
    """An undersized working set raises instead of dropping load, and
    the message carries the recent per-segment occupancy trace so the
    operator can see the backlog build-up, not just the failing seam."""
    _, trace, classes, plan = _mk(seed=7, compression=0.3, horizon=30.0)
    se = StreamingEngineJAX(classes, POLICIES["vllm"](plan),
                            EngineConfig(PRIM, PRICE, n_servers=N),
                            horizon=30.0, window=16)
    with pytest.raises(RuntimeError, match="window") as exc:
        se.run_stream(TraceChunkSource(_strip_patience(trace),
                                       chunk_size=64), seed=0)
    msg = str(exc.value)
    assert "occupancy after recent splices" in msg, msg
    # small chunks -> several splices before the overflow: the trace
    # must list seg<idx>=<occupancy> entries, not the first-splice text
    se2 = StreamingEngineJAX(classes, POLICIES["vllm"](plan),
                             EngineConfig(PRIM, PRICE, n_servers=N),
                             horizon=30.0, window=16)
    with pytest.raises(RuntimeError,
                       match=r"occupancy after recent splices: seg\d+=") \
            as exc2:
        se2.run_stream(TraceChunkSource(_strip_patience(trace),
                                        chunk_size=8), seed=0)
    assert re.search(r"seg\d+=\d+", str(exc2.value)), str(exc2.value)


# --------------------------------------------- registry regression (tier-1)

def test_registry_scenarios_budget_not_exhausted():
    """Every workload-registry scenario replays its CI-size trace to the
    horizon: the scan budget must never truncate the simulation, with
    the streamed generator-fed path used wherever it applies (infinite
    patience) and the host-padded engine covering the deadline
    scenarios."""
    from repro.workloads import get_scenario, list_scenarios
    from repro.workloads.batch import ScenarioStream

    horizon = 60.0
    for nm in list_scenarios():
        sc = get_scenario(nm)
        shares = np.array([p.share for p in sc.profiles])
        shares = shares / shares.sum()
        classes = [WorkloadClass(p.name, int(p.mean_prompt),
                                 int(p.mean_decode),
                                 max(float(2.0 * sh / 6), 1e-3))
                   for p, sh in zip(sc.profiles, shares)]
        plan = solve_bundled_lp(classes, PRIM, PRICE)
        cfg = EngineConfig(PRIM, PRICE, n_servers=6)
        streamable = all(np.isinf(p.patience) for p in sc.profiles)
        if streamable:
            eng = StreamingEngineJAX(classes, gate_and_route(plan), cfg,
                                     horizon=horizon, window=4096)
            m = eng.run_stream(ScenarioStream(sc, seed=0, chunk_size=512,
                                              horizon=horizon), seed=0)
        else:
            trace = sc.generate(seed=0, horizon=horizon)
            m = ClusterEngineJAX(classes, gate_and_route(plan), cfg,
                                 tensorize_trace(trace),
                                 horizon=horizon).run(0)
        assert m["budget_exhausted"] == 0.0, nm
        assert m["arrivals"] > 0, nm
