"""Pipeline-parallel schedule tests (multi-device via subprocess)."""

import subprocess
import sys

from repro.training.pipeline import bubble_fraction

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.training.pipeline import make_pipeline_forward

mesh = make_mesh((4,), ("pipe",))
S, n_micro, d = 4, 6, 8

# stage s applies y = x @ W_s (W stacked over stages)
key = jax.random.PRNGKey(0)
W = jax.random.normal(key, (S, d, d)) / np.sqrt(d)

def stage_fn(w_local, x, sid):
    return x @ w_local[0]

f = make_pipeline_forward(stage_fn, mesh, n_micro=n_micro, axis="pipe")
xs = jax.random.normal(jax.random.PRNGKey(1), (n_micro, 2, d))
out = f(W, xs)

ref = xs
for s in range(S):
    ref = jnp.einsum("mbd,de->mbe", ref, W[s])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                           rtol=1e-4)
print("PIPELINE_OK")
"""


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert abs(bubble_fraction(4, 12) - 3 / 15) < 1e-12
    assert bubble_fraction(8, 8) == 7 / 15


def test_pipeline_forward_matches_sequential():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
