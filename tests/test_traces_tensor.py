"""Property tests for trace tensorization + the shared trace validation.

Hypothesis-driven: pad/unpad round-trip, arrival-order preservation, and
cap accounting; plus deterministic tests for the validation path shared
by ``synth_azure_trace`` and ``load_trace_csv``."""

import numpy as np
import pytest

from repro.data.traces import (Request, TraceConfig, TraceValidationError,
                               chunk_trace, concat_chunks, load_trace_csv,
                               synth_azure_trace, tensorize_trace,
                               untensorize_trace, validate_requests)

hypothesis = pytest.importorskip(
    "hypothesis")  # property tests need hypothesis; skip where absent
from hypothesis import given, settings, strategies as st  # noqa: E402


@st.composite
def traces(draw, max_len=40):
    """Valid request lists: sorted finite arrivals, positive P/D."""
    n = draw(st.integers(0, max_len))
    ts = sorted(draw(st.lists(
        st.floats(0.0, 1e4, allow_nan=False, allow_infinity=False),
        min_size=n, max_size=n)))
    reqs = []
    for k in range(n):
        reqs.append(Request(
            rid=k,
            t_arrival=ts[k],
            cls=draw(st.integers(0, 3)),
            prompt_len=draw(st.integers(1, 5000)),
            decode_len=draw(st.integers(1, 800)),
            patience=draw(st.one_of(st.just(float("inf")),
                                    st.floats(0.1, 100.0))),
        ))
    return reqs


@settings(max_examples=60, deadline=None)
@given(traces())
def test_roundtrip(reqs):
    """untensorize(tensorize(reqs)) recovers every field except the rid
    labels, which are canonicalised to arrival order."""
    tt = tensorize_trace(reqs)
    back = untensorize_trace(tt)
    assert len(back) == len(reqs)
    for orig, rt in zip(reqs, back):
        assert rt.t_arrival == orig.t_arrival
        assert rt.cls == orig.cls
        assert rt.prompt_len == orig.prompt_len
        assert rt.decode_len == orig.decode_len
        assert rt.patience == orig.patience
    # canonical ids: arange in arrival order
    assert [r.rid for r in back] == list(range(len(reqs)))


@settings(max_examples=60, deadline=None)
@given(traces(), st.integers(0, 30))
def test_padding_and_order(reqs, extra_pad):
    """Padding never reorders arrivals or leaks into the valid region."""
    tt = tensorize_trace(reqs, pad_to=len(reqs) + extra_pad)
    assert tt.R == len(reqs) + extra_pad
    assert tt.n_real == len(reqs)
    assert tt.valid.sum() == len(reqs)
    assert not tt.valid[len(reqs):].any()
    # arrival times are nondecreasing over the valid prefix and +inf after
    assert (np.diff(tt.t[: len(reqs)]) >= 0).all()
    assert np.isinf(tt.t[len(reqs):]).all()
    assert (tt.P >= 1).all() and (tt.D >= 1).all()


@settings(max_examples=60, deadline=None)
@given(traces(), st.integers(1, 20))
def test_max_requests_cap(reqs, cap):
    """The cap keeps the earliest arrivals and reports the overflow."""
    tt = tensorize_trace(reqs, max_requests=cap)
    kept = min(len(reqs), cap)
    assert tt.n_real == kept
    assert tt.n_dropped == max(0, len(reqs) - cap)
    np.testing.assert_allclose(
        tt.t[:kept], [r.t_arrival for r in reqs[:kept]])


def test_pad_to_too_small_rejected():
    reqs = [Request(0, 0.0, 0, 10, 5), Request(1, 1.0, 0, 10, 5)]
    with pytest.raises(TraceValidationError, match="pad_to"):
        tensorize_trace(reqs, pad_to=1)


# ---------------------------------------------------------------------------
# Shared validation path (synth + CSV + tensorize)
# ---------------------------------------------------------------------------


def test_validate_rejects_nonmonotone():
    reqs = [Request(0, 5.0, 0, 10, 5), Request(1, 1.0, 0, 10, 5)]
    with pytest.raises(TraceValidationError, match="nondecreasing"):
        validate_requests(reqs)
    with pytest.raises(TraceValidationError, match="nondecreasing"):
        tensorize_trace(reqs)


@pytest.mark.parametrize("bad,msg", [
    (Request(0, float("nan"), 0, 10, 5), "non-finite"),
    (Request(0, -1.0, 0, 10, 5), "non-finite or negative"),
    (Request(0, 0.0, 0, 0, 5), "token lengths"),
    (Request(0, 0.0, 0, 10, 0), "token lengths"),
    (Request(0, 0.0, 0, 10, 5, patience=0.0), "patience"),
    (Request(0, 0.0, -1, 10, 5), "negative"),
])
def test_validate_rejects_bad_fields(bad, msg):
    with pytest.raises(TraceValidationError, match=msg):
        validate_requests([bad])


def test_synth_trace_passes_validation():
    trace = synth_azure_trace(TraceConfig(horizon=5.0, compression=0.5))
    validate_requests(trace)  # idempotent: synth already validates


# ---------------------------------------------------------------------------
# Chunked TraceTensors (streamed-replay input format)
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(traces(), st.integers(1, 17))
def test_chunk_concat_roundtrip(reqs, chunk_size):
    """concat(chunk(reqs)) is the unchunked tensorization, whatever the
    chunk size -- requests crossing chunk boundaries included."""
    chunks = chunk_trace(reqs, chunk_size)
    assert all(c.R == chunk_size for c in chunks)
    assert sum(c.n_real for c in chunks) == len(reqs)
    whole = concat_chunks(chunks)
    ref = tensorize_trace(reqs)
    assert whole.n_real == ref.n_real
    for field in ("t", "cls", "P", "D", "patience", "valid"):
        np.testing.assert_array_equal(
            getattr(whole, field)[:whole.n_real],
            getattr(ref, field)[:ref.n_real], err_msg=field)


def test_chunk_trace_shapes_and_edges():
    reqs = [Request(k, float(k), 0, 10, 5) for k in range(5)]
    assert len(chunk_trace(reqs, 2)) == 3  # last chunk half-empty
    assert chunk_trace(reqs, 2)[-1].n_real == 1
    empty = chunk_trace([], 4)
    assert len(empty) == 1 and empty[0].n_real == 0  # one all-pad chunk
    with pytest.raises(ValueError, match="chunk_size"):
        chunk_trace(reqs, 0)


def test_concat_rejects_nonmonotone_seams():
    a = chunk_trace([Request(0, 5.0, 0, 10, 5)], 2)[0]
    b = chunk_trace([Request(0, 1.0, 0, 10, 5)], 2)[0]
    with pytest.raises(TraceValidationError):
        concat_chunks([a, b])
    with pytest.raises(TraceValidationError):
        concat_chunks([])


def test_csv_loader_validates(tmp_path):
    good = tmp_path / "good.csv"
    good.write_text("t,class,P,D\n0.5,code,100,10\n0.1,chat,50,5\n")
    reqs = load_trace_csv(str(good))
    assert [r.t_arrival for r in reqs] == [0.1, 0.5]  # sorted on load
    bad = tmp_path / "bad.csv"
    bad.write_text("t,class,P,D\n0.5,code,0,10\n")
    with pytest.raises(TraceValidationError, match="token lengths"):
        load_trace_csv(str(bad))
    nan = tmp_path / "nan.csv"
    nan.write_text("t,class,P,D\nnan,code,100,10\n")
    with pytest.raises(TraceValidationError, match="non-finite"):
        load_trace_csv(str(nan))
