"""Hypothesis property tests on the system's invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis; skip where absent
from hypothesis import given, settings, strategies as st

from repro.core.lp import linprog_max
from repro.core.planning import SLISpec, solve_bundled_lp, solve_separate_lp
from repro.core.simulator import CTMCSimulator
from repro.core.policies import gate_and_route
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass
from repro.launch.hlo_analysis import collective_traffic


def _classes(draw_lens, rates, theta=0.1):
    return [
        WorkloadClass(f"c{i}", P, D, lam, theta)
        for i, ((P, D), lam) in enumerate(zip(draw_lens, rates))
    ]


cls_strategy = st.lists(
    st.tuples(st.integers(50, 4000), st.integers(10, 1500)),
    min_size=1, max_size=4)
rate_strategy = st.floats(0.01, 2.0)


@given(lens=cls_strategy, lam=rate_strategy,
       b=st.integers(2, 32), c=st.integers(32, 512))
@settings(max_examples=40, deadline=None)
def test_lp_feasibility_invariants(lens, lam, b, c):
    """LP solutions always satisfy the paper's capacity constraints."""
    prim = ServicePrimitives(batch_cap=b, chunk=c)
    classes = _classes(lens, [lam] * len(lens))
    plan = solve_bundled_lp(classes, prim, Pricing())
    B = prim.batch_cap
    assert plan.x.sum() <= 1 + 1e-8
    assert plan.ym.sum() <= (B - 1) * plan.x.sum() + 1e-6
    assert plan.ys.sum() <= B * (1 - plan.x.sum()) + 1e-6
    assert (plan.x >= -1e-9).all() and (plan.qp >= -1e-9).all()
    # revenue is bounded by serving everything: sum_i w_i * lambda_i
    ub = sum(Pricing().bundled_reward(k) * k.arrival_rate for k in classes)
    assert plan.revenue_rate <= ub + 1e-6


@given(lens=cls_strategy, lam=rate_strategy)
@settings(max_examples=25, deadline=None)
def test_decode_buffer_elimination(lens, lam):
    """Prop 1: in the calibrated regime (gamma*tau >= (B-1)/B) there is an
    optimal plan with q_d = 0 -- pinning q_d = 0 must not lose revenue."""
    prim = ServicePrimitives()
    assert prim.solo_efficiency_ok
    classes = _classes(lens, [lam] * len(lens))
    free = solve_bundled_lp(classes, prim, Pricing())
    pinned = solve_bundled_lp(classes, prim, Pricing(),
                              sli=SLISpec(pin_zero_decode_queue=True))
    assert pinned.revenue_rate >= free.revenue_rate - 1e-6 * max(
        1.0, abs(free.revenue_rate))


@given(lens=cls_strategy, lam=rate_strategy)
@settings(max_examples=15, deadline=None)
def test_separate_charging_dominates_bundled_value(lens, lam):
    """Separate charging recognises prefill value too, so its optimal
    fluid value is >= the bundled optimum on the same instance."""
    prim = ServicePrimitives()
    classes = _classes(lens, [lam] * len(lens))
    b = solve_bundled_lp(classes, prim, Pricing())
    s = solve_separate_lp(classes, prim, Pricing())
    assert s.revenue_rate >= b.revenue_rate - 1e-6 * max(
        1.0, abs(b.revenue_rate))


@given(lens=st.lists(st.tuples(st.integers(100, 2000),
                               st.integers(20, 800)),
                     min_size=1, max_size=3),
       lam=st.floats(0.05, 0.8), seed=st.integers(0, 3))
@settings(max_examples=8, deadline=None)
def test_ctmc_conservation(lens, lam, seed):
    """Pathwise flow balance: arrivals = in-flight + completions +
    abandons at every stopping time (checked at the horizon)."""
    prim = ServicePrimitives(batch_cap=8)
    classes = _classes(lens, [lam] * len(lens))
    plan = solve_bundled_lp(classes, prim, Pricing())
    sim = CTMCSimulator(classes, prim, Pricing(), gate_and_route(plan),
                        n=20, seed=seed)
    r = sim.run(horizon=40.0)
    in_flight = (sim.Qp + sim.X + sim.Qdm + sim.Qds + sim.Ym + sim.Ys)
    lhs = r.arrivals
    rhs = in_flight + r.completions + r.abandons_p + r.abandons_d
    np.testing.assert_allclose(lhs, rhs, atol=1e-9)
    # capacity invariants held at the end state
    assert sim.X.sum() <= sim.M + 1e-9
    assert sim.Ym.sum() <= (prim.batch_cap - 1) * sim.M + 1e-9
    assert sim.Ys.sum() <= prim.batch_cap * (sim.n - sim.M) + 1e-9


@given(st.integers(2, 64), st.integers(2, 64), st.integers(2, 16),
       st.sampled_from(["f32", "bf16"]))
@settings(max_examples=30, deadline=None)
def test_collective_parser_allreduce_factor(m, n, k, dt):
    """all-reduce traffic = 2 (k-1)/k * payload for any iota group."""
    bytes_per = {"f32": 4, "bf16": 2}[dt]
    line = (f"  %all-reduce.1 = {dt}[{m},{n}]{{1,0}} all-reduce(%x), "
            f"channel_id=1, replica_groups=[2,{k}]<=[{2*k}], "
            f"use_global_device_ids=true, to_apply=%add")
    out = collective_traffic(line)
    expect = 2 * (k - 1) / k * m * n * bytes_per
    np.testing.assert_allclose(out["all-reduce"], expect)
    assert out["total"] == out["all-reduce"]


@given(st.integers(1, 6), st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_lp_solver_vs_bruteforce_2d(a, b):
    """Tiny LP sanity: max x+y s.t. x<=a, y<=b, x,y>=0 -> a+b."""
    import numpy as np
    c = np.array([1.0, 1.0])
    A = np.array([[1.0, 0.0], [0.0, 1.0]])
    res = linprog_max(c, A, np.array([float(a), float(b)]),
                      np.zeros((0, 2)), np.zeros(0))
    np.testing.assert_allclose(res.fun, a + b, rtol=1e-9, atol=1e-9)
