"""Online controller + elasticity integration tests."""

import numpy as np
import pytest

from repro.core.online import OnlineController, OnlineControllerConfig
from repro.core.planning import solve_bundled_lp
from repro.core.policies import gate_and_route
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass
from repro.data.traces import Request, TraceConfig, synth_azure_trace
from repro.serving.engine_sim import ClusterEngine, EngineConfig

pytestmark = pytest.mark.sim

PRIM = ServicePrimitives()
PRICING = Pricing()


def _classes(rate=0.5):
    return [WorkloadClass("a", 2048, 36, rate, 3e-4),
            WorkloadClass("b", 1020, 211, rate, 3e-4)]


def test_rate_estimator_converges():
    classes = _classes()
    ctrl = OnlineController(classes, PRIM, PRICING, n=10,
                            config=OnlineControllerConfig(safety=1.0))
    rng = np.random.default_rng(0)
    t = 0.0
    true_rates = [4.0, 7.0]  # cluster-level
    for _ in range(2000):
        i = 0 if rng.random() < true_rates[0] / sum(true_rates) else 1
        t += rng.exponential(1.0 / sum(true_rates))
        ctrl.observe_arrival(t, i)
    lam = ctrl.estimate_rates(t)  # per-server estimates
    np.testing.assert_allclose(lam * 10, true_rates, rtol=0.25)


def test_replan_cadence_and_capacity_hook():
    classes = _classes()
    ctrl = OnlineController(classes, PRIM, PRICING, n=10,
                            config=OnlineControllerConfig(replan_every=10.0))
    assert ctrl.maybe_replan(0.0) is not None
    assert ctrl.maybe_replan(5.0) is None
    assert ctrl.maybe_replan(10.0) is not None
    n_replans = ctrl.replan_count
    ctrl.set_capacity(7, 12.0)  # failure -> immediate replan
    assert ctrl.replan_count == n_replans + 1
    assert ctrl.mixed_target() <= 7


def test_failure_requeues_and_completes():
    """Jobs on a failed server are re-prefilled and still complete."""
    classes = _classes(rate=0.05)
    plan = solve_bundled_lp(classes, PRIM, PRICING)
    reqs = [Request(i, 0.1 * i, i % 2, 512, 16, patience=float("inf"))
            for i in range(20)]
    evs = [(1.0, "fail", 0), (1.0, "fail", 1), (30.0, "recover", 0),
           (30.0, "recover", 1)]
    eng = ClusterEngine(classes, gate_and_route(plan),
                        EngineConfig(PRIM, PRICING, 4, seed=0))
    m = eng.run(reqs, horizon=4000.0, failure_events=evs, drain=True)
    assert m.completions == 20
    assert m.abandons == 0


def test_straggler_slows_but_preserves_work():
    """A slowed server stretches its own latency ~proportionally but no
    work is lost (single-server cluster pins the work to the straggler)."""
    classes = _classes(rate=0.05)
    plan = solve_bundled_lp(classes, PRIM, PRICING)
    reqs = [Request(i, 0.05 * i, i % 2, 256, 32, patience=float("inf"))
            for i in range(8)]

    def run(evs):
        eng = ClusterEngine(classes, gate_and_route(plan),
                            EngineConfig(PRIM, PRICING, 1, seed=0))
        return eng.run(reqs, horizon=8000.0, failure_events=evs, drain=True)

    healthy = run([])
    slow = run([(0.0, "straggle", 0, 4.0)])
    assert slow.completions == healthy.completions == 8
    ratio = np.mean(slow.tpot) / np.mean(healthy.tpot)
    assert 2.0 < ratio < 6.0  # ~4x slower iterations
