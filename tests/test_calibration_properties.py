"""Hypothesis property tests for the calibration subsystem.

Three paper-level invariants, each over randomized inputs:

1. **Planted-parameter recovery** -- the robust fitter recovers
   ``(alpha, beta, a_s, b_s)`` from synthetic noisy timings of the true
   affine surfaces (within a noise-scaled tolerance, even with an
   injected outlier the Huber weights must down-weight).
2. **Positivity and monotonicity** -- fitted tau surfaces are positive
   and non-decreasing in ``C`` and ``K`` over the grid's range, for both
   the fitted-affine and the table model.
3. **Lossless artifact round-trip** -- ``CalibrationArtifact`` survives
   JSON serialisation exactly (``from_json(to_json(a)) == a``), floats
   included.

Importorskips hypothesis (the ``tests/test_lp_jax_properties.py``
pattern) so deterministic environments without it still collect.
"""

import math

import pytest

hypothesis = pytest.importorskip(
    "hypothesis")  # property tests need hypothesis; skip where absent
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.calibration import (CalibrationArtifact,  # noqa: E402
                               CalibrationGrid, Sample, fit_surfaces,
                               model_from_artifact)
from repro.launch.mesh import v5e_constants  # noqa: E402


def _lcg(seed):
    """Tiny deterministic PRNG (keeps hypothesis shrinking stable)."""
    state = seed or 1

    def rnd():
        nonlocal state
        state = (1103515245 * state + 12345) % (1 << 31)
        return state / float(1 << 31)

    return rnd


@st.composite
def planted_surfaces(draw):
    alpha = draw(st.floats(1e-3, 5e-2))
    beta = draw(st.floats(1e-7, 1e-4))
    a_s = draw(st.floats(1e-3, 2e-2))
    b_s = draw(st.floats(1e-9, 1e-6))
    noise = draw(st.floats(0.0, 0.02))  # relative noise scale
    seed = draw(st.integers(0, 2**31 - 1))
    return alpha, beta, a_s, b_s, noise, seed


def _samples_for(alpha, beta, a_s, b_s, noise, seed,
                 grid=None):
    grid = grid or CalibrationGrid.default()
    rnd = _lcg(seed)
    out = []
    for cell in grid.cells():
        tau = (alpha + beta * cell.chunk if cell.mode == "mixed"
               else a_s + b_s * cell.kv)
        tau *= 1.0 + noise * (2.0 * rnd() - 1.0)
        out.append(Sample(mode=cell.mode, batch=cell.batch,
                          chunk=cell.chunk, kv=cell.kv, tau=tau,
                          backend="roofline"))
    return grid, out


@settings(max_examples=25, deadline=None)
@given(planted_surfaces())
def test_fitter_recovers_planted_parameters(p):
    alpha, beta, a_s, b_s, noise, seed = p
    grid, samples = _samples_for(alpha, beta, a_s, b_s, noise, seed)
    fits = fit_surfaces(samples)
    # tolerance scales with the injected noise; exact when noise == 0
    tol = 1e-9 + 5.0 * noise
    assert fits["mix"].intercept == pytest.approx(alpha, rel=tol, abs=tol)
    assert fits["solo"].intercept == pytest.approx(a_s, rel=tol, abs=tol)
    # slopes: compare through the surface values at the grid extremes
    # (slope itself is ill-conditioned when beta * C << alpha)
    c_hi, k_hi = max(grid.chunk), max(grid.kv)
    assert fits["mix"](c_hi) == pytest.approx(
        alpha + beta * c_hi, rel=tol, abs=tol * alpha)
    assert fits["solo"](k_hi) == pytest.approx(
        a_s + b_s * k_hi, rel=tol, abs=tol * a_s)
    if noise == 0.0:
        assert fits["mix"].r2 == pytest.approx(1.0, abs=1e-9)
        assert fits["solo"].r2 == pytest.approx(1.0, abs=1e-9)


@settings(max_examples=20, deadline=None)
@given(planted_surfaces())
def test_fitter_survives_one_outlier(p):
    """A single corrupted cell (10x the true time) must not tilt the
    surface by more than a few percent -- the Huber IRLS down-weights it."""
    alpha, beta, a_s, b_s, _, seed = p
    _, samples = _samples_for(alpha, beta, a_s, b_s, 0.0, seed)
    mixed = [s for s in samples if s.mode == "mixed"]
    bad = mixed[seed % len(mixed)]
    samples[samples.index(bad)] = Sample(
        mode=bad.mode, batch=bad.batch, chunk=bad.chunk, kv=bad.kv,
        tau=bad.tau * 10.0, backend=bad.backend)
    fits = fit_surfaces(samples)
    assert fits["mix"].intercept == pytest.approx(alpha, rel=0.05,
                                                 abs=0.05 * alpha)


@settings(max_examples=25, deadline=None)
@given(planted_surfaces(), st.sampled_from(["fitted", "table"]))
def test_fitted_surfaces_positive_and_monotone(p, kind):
    alpha, beta, a_s, b_s, noise, seed = p
    grid, samples = _samples_for(alpha, beta, a_s, b_s, noise, seed)
    fits = fit_surfaces(samples)
    art = CalibrationArtifact(
        arch="qwen2-0.5b", backend="roofline", grid=grid,
        samples=tuple(samples), mix=fits["mix"], solo=fits["solo"],
        hw={k: float(v) for k, v in v5e_constants().items()})
    m = model_from_artifact(art, kind)
    cs = [1, 16, 64, 256, 512, 1024]
    ks = [0, 128, 1024, 8192, 65536]
    taus_c = [m.tau_mix(c) for c in cs]
    taus_k = [m.tau_solo(k) for k in ks]
    assert all(t > 0 and math.isfinite(t) for t in taus_c + taus_k)
    if kind == "fitted":  # affine fits clamp negative slopes
        assert all(b >= a for a, b in zip(taus_c, taus_c[1:]))
        assert all(b >= a for a, b in zip(taus_k, taus_k[1:]))


@settings(max_examples=25, deadline=None)
@given(planted_surfaces())
def test_artifact_json_round_trip_lossless(p):
    alpha, beta, a_s, b_s, noise, seed = p
    grid, samples = _samples_for(alpha, beta, a_s, b_s, noise, seed)
    fits = fit_surfaces(samples)
    art = CalibrationArtifact(
        arch="gemma2-2b", backend="roofline", grid=grid,
        samples=tuple(samples), mix=fits["mix"], solo=fits["solo"],
        hw={k: float(v) for k, v in v5e_constants().items()},
        created="2026-08-09T00:00:00")
    again = CalibrationArtifact.from_json(art.to_json())
    assert again == art  # dataclass equality: every float bit-exact
    # and a second hop is a fixed point
    assert CalibrationArtifact.from_json(again.to_json()) == art


# deterministic fitter/model edge cases live in tests/test_calibration.py
# (they must run even where hypothesis is absent)
