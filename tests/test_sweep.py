"""Sweep subsystem tests: determinism, schema round-trip, and exact
equivalence of a batched sweep cell against a direct CTMCSimulator run."""

import json

import numpy as np
import pytest

from repro.core.simulator import CTMCSimulator
from repro.sweep import (MixSpec, SweepResult, SweepSchemaError, SweepSpec,
                         cell_seed_sequence, run_sweep, validate_payload)
from repro.sweep.evaluators import (MixContext, parse_policy_token,
                                    resolve_policy)
from repro.sweep.run import default_mix

pytestmark = pytest.mark.sim


def small_spec(**kw) -> SweepSpec:
    base = dict(name="t", evaluator="ctmc",
                policies=("gate_and_route", "FG-SP"),
                n_servers=(10, 20), n_seeds=2, seed=123,
                mixes=(default_mix("two_class"),),
                horizon=10.0, warmup=2.0)
    base.update(kw)
    return SweepSpec(**base)


@pytest.fixture(scope="module")
def result() -> SweepResult:
    return run_sweep(small_spec())


def test_grid_is_complete(result):
    spec = result.spec
    assert len(result.cells) == spec.n_cells
    for pol in spec.policies:
        for n in spec.n_servers:
            assert len(result.select(policy=pol, n=n)) == spec.n_seeds


def test_determinism_same_spec_same_fingerprint(result):
    again = run_sweep(small_spec())
    assert again.fingerprint() == result.fingerprint()
    # ...and a different master seed perturbs the cells
    other = run_sweep(small_spec(seed=124))
    assert other.fingerprint() != result.fingerprint()


def test_seed_streams_are_coordinate_keyed():
    spec = small_spec()
    a = cell_seed_sequence(spec, 0, 1, 1, 0)
    b = cell_seed_sequence(spec, 0, 1, 1, 0)
    c = cell_seed_sequence(spec, 0, 1, 1, 1)
    assert a.entropy == b.entropy
    assert np.random.default_rng(a).random() == np.random.default_rng(b).random()
    assert np.random.default_rng(a).random() != np.random.default_rng(c).random()


def test_batched_cell_equals_direct_ctmc_run(result):
    """A sweep cell must be bitwise-reproducible by a standalone
    CTMCSimulator run seeded with the cell's SeedSequence."""
    spec = result.spec
    mix_i, policy_i, n_i, seed_i = 0, 0, 1, 1
    token, n = spec.policies[policy_i], spec.n_servers[n_i]
    ctx = MixContext(spec.mixes[mix_i], spec)
    policy = resolve_policy(token, ctx, n)
    ss = cell_seed_sequence(spec, mix_i, policy_i, n_i, seed_i)
    direct = CTMCSimulator(ctx.classes, ctx.prim, ctx.pricing, policy,
                           n=n, seed=ss).run(spec.horizon, warmup=spec.warmup)
    (cell,) = result.select(policy=token, n=n, seed=seed_i)
    assert cell.metrics["revenue_rate"] == direct.revenue_rate_per_server
    assert cell.metrics["completions"] == direct.completions.sum()
    for i in range(len(ctx.classes)):
        assert cell.metrics[f"avg_x/{i}"] == direct.avg_x[i]


def test_json_round_trip(tmp_path, result):
    path = result.save(tmp_path / "sweep.json")
    loaded = SweepResult.load(path)
    assert loaded.spec == result.spec
    assert loaded.fingerprint() == result.fingerprint()
    validate_payload(json.loads(path.read_text()))


def test_schema_validation_rejects_corruption(result):
    payload = result.to_payload()
    for mutate in (
        lambda p: p.pop("schema_version"),
        lambda p: p["cells"][0].pop("metrics"),
        lambda p: p["cells"][0]["metrics"].update(bad="not-a-number"),
        lambda p: p["cells"][0].update(policy="never-declared"),
        lambda p: p["spec"].update(evaluator="teleport"),
    ):
        bad = json.loads(json.dumps(payload))
        mutate(bad)
        with pytest.raises(SweepSchemaError):
            validate_payload(bad)


def test_non_finite_metrics_serialize_as_null(tmp_path, result):
    from repro.sweep import CellResult

    res = SweepResult(spec=result.spec, cells=[
        CellResult("two_class", "gate_and_route", 10, 0,
                   {"revenue_rate": 1.0, "ttft_mean": float("nan")})])
    path = res.save(tmp_path / "nan.json")
    raw = path.read_text()
    assert "NaN" not in raw and '"ttft_mean": null' in raw
    loaded = SweepResult.load(path)
    assert np.isnan(loaded.cells[0].metrics["ttft_mean"])


def test_crn_policies_pairs_streams_across_policy_axis():
    paired = run_sweep(small_spec(extra={"crn_policies": True},
                                  policies=("FG-SP", "FG-SP")))
    a = paired.metric("revenue_rate", policy="FG-SP", n=10)
    # both policy columns are the same token under identical streams
    assert a.size == 4 and np.array_equal(a[:2], a[2:])


def test_policy_tokens():
    assert parse_policy_token("distserve_mix_solo:frac=0.2") == (
        "distserve_mix_solo", {"frac": 0.2})
    spec = small_spec()
    ctx = MixContext(spec.mixes[0], spec)
    pol = resolve_policy("distserve_mix_solo:frac=0.2", ctx, 20)
    assert pol.partition == "fixed:4"
    pol = resolve_policy("distserve_mix_solo:k=3", ctx, 20)
    assert pol.partition == "fixed:3"
    with pytest.raises(ValueError):
        resolve_policy("no_such_policy", ctx, 20)


def test_run_batch_reuses_simulator_state():
    spec = small_spec()
    ctx = MixContext(spec.mixes[0], spec)
    policy = resolve_policy("gate_and_route", ctx, 10)
    sim = CTMCSimulator(ctx.classes, ctx.prim, ctx.pricing, policy, n=10,
                        seed=0)
    ss = np.random.SeedSequence(5)
    a, b = sim.run_batch(5.0, rngs=ss.spawn(2))
    c, d = sim.run_batch(5.0, rngs=np.random.SeedSequence(5).spawn(2))
    assert a.revenue == c.revenue and b.revenue == d.revenue
    # distinct streams genuinely differ
    assert a.revenue != b.revenue


def test_fluid_batch_matches_single_integration():
    from repro.core.fluid import integrate_fluid
    from repro.sweep.fluid_batch import evaluate_fluid_grid

    spec = small_spec(evaluator="fluid", policies=("gate_and_route",),
                      horizon=50.0)
    ctx = MixContext(spec.mixes[0], spec)
    grid = evaluate_fluid_grid([ctx], ["gate_and_route"], 50.0, 2e-3)
    single = integrate_fluid(ctx.classes, ctx.prim, ctx.pricing,
                             ctx.plan("base"), horizon=50.0, dt=2e-3)
    m = grid[(0, 0)]
    # float32 scan: vmapped and serial accumulation orders differ slightly
    np.testing.assert_allclose(m["revenue_rate"], single.revenue_rate[-1],
                               rtol=1e-4)
    for i in range(len(ctx.classes)):
        np.testing.assert_allclose(m[f"avg_x/{i}"], single.x[-1, i],
                                   rtol=1e-4, atol=1e-6)


def test_lp_sweep_is_deterministic_and_replicated():
    spec = small_spec(evaluator="lp", policies=("lp",), n_servers=(1,),
                      n_seeds=3)
    res = run_sweep(spec)
    revs = res.metric("revenue", policy="lp", n=1)
    assert revs.size == 3 and np.all(revs == revs[0])


def test_cli_smoke(tmp_path):
    from repro.sweep.run import main

    out = tmp_path / "smoke.json"
    assert main(["--smoke", "--out", str(out)]) == 0
    loaded = SweepResult.load(out)
    assert loaded.cells and "revenue_rate" in loaded.cells[0].metrics
    # an out-of-repo artifact carries its manifest next to itself; the
    # repo-central artifacts/manifests/runs.jsonl must stay untouched
    from repro.telemetry.manifest import read_records
    (rec,) = read_records(tmp_path / "smoke.runs.jsonl")
    assert rec["kind"] == "sweep" and str(out) in rec["artifacts"]


# ---------------------------------------------------------------------------
# Scenario axis (repro.workloads registry as the trace source)
# ---------------------------------------------------------------------------


def test_mixspec_scenario_round_trips():
    mix = MixSpec(name="rate_shift", scenario="rate_shift",
                  trace={"rate_scale": 0.5})
    again = MixSpec.from_dict(mix.to_dict())
    assert again == mix
    # legacy payloads (no scenario key) still load
    assert MixSpec.from_dict({"name": "m"}).scenario == ""


def test_mixcontext_generates_from_scenario_registry():
    from repro.workloads import get_scenario

    mix = MixSpec(name="rate_shift", scenario="rate_shift",
                  trace={"seed": 4, "horizon": 30.0, "rate_scale": 0.5})
    ctx = MixContext(mix, small_spec(evaluator="engine", mixes=(mix,),
                                     policies=("gate_and_route",)))
    trace = ctx.trace(10)
    direct = get_scenario("rate_shift").generate(seed=4, horizon=30.0,
                                                 rate_scale=0.5)
    assert [(r.t_arrival, r.cls) for r in trace] == \
           [(r.t_arrival, r.cls) for r in direct]
    assert ctx.trace(10) is trace  # cached per n


def test_mixcontext_rejects_foreign_overrides_with_scenario():
    mix = MixSpec(name="bad", scenario="rate_shift",
                  trace={"base_rate": 3.0})
    ctx = MixContext(mix, small_spec(evaluator="engine", mixes=(mix,),
                                     policies=("gate_and_route",)))
    with pytest.raises(ValueError, match="base_rate"):
        ctx.trace(10)


def test_scenario_axis_sweep_engine_jax(tmp_path):
    """One tiny engine_jax sweep over two scenario mixes end to end."""
    mixes = tuple(
        MixSpec(name=s, scenario=s,
                trace={"horizon": 15.0, "rate_scale": 0.4})
        for s in ("rate_shift", "flash_crowd"))
    spec = small_spec(evaluator="engine_jax", mixes=mixes,
                      policies=("gate_and_route",), n_servers=(4,),
                      n_seeds=1, horizon=15.0, warmup=0.0)
    res = run_sweep(spec)
    assert len(res.cells) == 2
    for c in res.cells:
        assert c.metrics["completions"] > 0
        assert c.metrics["budget_exhausted"] == 0.0
    path = tmp_path / "scen.json"
    res.save(path)
    validate_payload(json.loads(path.read_text()))


def test_engine_jax_evaluator_hot_path_extra():
    """The hot-path switches (``k_events``, ``fastforward``) flow through
    ``spec.extra["engine_jax"]`` into the engine and replay the same
    arrivals as the default one-event path."""
    kw = dict(evaluator="engine_jax", policies=("gate_and_route",),
              n_servers=(4,), n_seeds=1, horizon=10.0, warmup=0.0,
              mixes=(default_mix("two_class"),))
    base = run_sweep(small_spec(**kw))
    hot = run_sweep(small_spec(
        **kw, extra={"engine_jax": {"k_events": 2, "fastforward": True}}))
    assert len(base.cells) == len(hot.cells) == 1
    assert (hot.cells[0].metrics["arrivals"]
            == base.cells[0].metrics["arrivals"])
    assert hot.cells[0].metrics["budget_exhausted"] == 0.0


def test_cli_scenarios_flag_requires_engine_evaluator(tmp_path):
    from repro.sweep.run import main

    with pytest.raises(SystemExit):
        main(["--scenarios", "rate_shift", "--evaluator", "ctmc",
              "--out", str(tmp_path / "x.json")])
