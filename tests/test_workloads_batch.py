"""Vmapped (seeds x scenarios) JAX trace generation."""

import numpy as np
import pytest

from repro.data.traces import validate_requests
from repro.workloads import get_scenario
from repro.workloads.batch import (batch_cell_requests, batch_cell_tensors,
                                   generate_batch)

pytestmark = pytest.mark.sim

NAMES = ("rate_shift", "flash_crowd", "azure_2023", "dolly_mix")


@pytest.fixture(scope="module")
def batch():
    scns = [get_scenario(n) for n in NAMES]
    return generate_batch(scns, seeds=[0, 1, 2], horizon=90.0,
                          rate_scale=0.5)


def test_batch_shapes_and_budget(batch):
    S, K, R = batch["t"].shape
    assert (S, K) == (len(NAMES), 3)
    assert R == batch["meta"]["R"]
    assert batch["truncated"].sum() == 0  # candidate budget covered
    assert (batch["n_real"] > 0).all()


def test_batch_cells_are_valid_traces(batch):
    for s in range(len(NAMES)):
        scn = get_scenario(NAMES[s])
        for k in range(3):
            reqs = batch_cell_requests(batch, s, k)  # validates internally
            validate_requests(reqs)
            assert len(reqs) == int(batch["n_real"][s, k])
            assert all(r.cls < scn.n_classes for r in reqs)
            tt = batch_cell_tensors(batch, s, k)
            assert tt.n_real == len(reqs)
            assert np.isinf(tt.t[~tt.valid]).all()
            assert (tt.P >= 1).all() and (tt.D >= 1).all()


def test_batch_counts_match_rate_integral(batch):
    """Mean accepted count ~= integral of the (scaled) intensity."""
    for s, name in enumerate(NAMES):
        proc = get_scenario(name).arrivals.scaled(0.5)
        h = min(90.0, get_scenario(name).horizon)
        expect = proc.mean_rate(h) * h
        got = batch["n_real"][s].mean()
        sigma = np.sqrt(expect)
        assert abs(got - expect) < 6 * sigma, (name, got, expect)


def test_batch_deterministic_and_seed_sensitive():
    scns = [get_scenario("rate_shift")]
    a = generate_batch(scns, seeds=[7], horizon=40.0)
    b = generate_batch(scns, seeds=[7], horizon=40.0)
    c = generate_batch(scns, seeds=[8], horizon=40.0)
    np.testing.assert_array_equal(a["t"], b["t"])
    np.testing.assert_array_equal(a["P"], b["P"])
    assert not np.array_equal(a["t"], c["t"])


def test_batch_patience_and_mix(batch):
    # dolly_mix has finite per-class patience; azure does not
    s_dolly = NAMES.index("dolly_mix")
    s_azure = NAMES.index("azure_2023")
    v = batch["valid"][s_dolly, 0]
    assert np.isfinite(batch["patience"][s_dolly, 0][v]).all()
    v = batch["valid"][s_azure, 0]
    assert np.isinf(batch["patience"][s_azure, 0][v]).all()
    # rate_shift mix flips: early arrivals mostly class 0
    s_rs = NAMES.index("rate_shift")
    t = batch["t"][s_rs, 0]
    cls = batch["cls"][s_rs, 0]
    v = batch["valid"][s_rs, 0]
    early = cls[v & (t < 60.0)]
    assert early.size and early.mean() < 0.4


def test_batch_rejects_empty():
    with pytest.raises(ValueError):
        generate_batch([], seeds=[0])
    with pytest.raises(ValueError):
        generate_batch([get_scenario("rate_shift")], seeds=[])
