"""Config registry / shape / dry-run-support tests (no big compiles)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCHS, SHAPES, all_cells, get_config, input_specs,
                           skip_reason, supported_shapes)
from repro.launch.hlo_analysis import collective_traffic
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_terms
from repro.models.config import segment_layers


def test_registry_complete():
    assert len(ARCHS) == 10
    assert len(SHAPES) == 4
    assert len(all_cells()) == 40


def test_exact_published_configs():
    spec = {
        "whisper-base": (6, 512, 2048, 51865),
        "deepseek-v3-671b": (61, 7168, 18432, 129280),
        "grok-1-314b": (64, 6144, 32768, 131072),
        "deepseek-67b": (95, 8192, 22016, 102400),
        "qwen2-0.5b": (24, 896, 4864, 151936),
        "gemma2-2b": (26, 2304, 9216, 256000),
        "phi4-mini-3.8b": (32, 3072, 8192, 200064),
        "recurrentgemma-2b": (26, 2560, 7680, 256000),
        "mamba2-130m": (24, 768, 0, 50280),
        "paligemma-3b": (18, 2048, 16384, 257216),
    }
    for arch, (L, d, ff, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size) == (
            L, d, ff, V), arch
    # head / kv-head / MoE structure
    assert get_config("deepseek-v3-671b").mla.n_heads == 128
    assert get_config("deepseek-v3-671b").moe.n_experts == 256
    assert get_config("deepseek-v3-671b").moe.top_k == 8
    assert get_config("grok-1-314b").attn.n_kv_heads == 8
    assert get_config("grok-1-314b").moe.top_k == 2
    assert get_config("qwen2-0.5b").attn.qkv_bias
    assert get_config("gemma2-2b").logit_softcap == 30.0
    assert get_config("recurrentgemma-2b").pattern == ("rec", "rec",
                                                       "attn_local")
    assert get_config("mamba2-130m").ssm.d_state == 128
    assert get_config("paligemma-3b").vision.n_patches == 256


def test_long_500k_skip_rules():
    """long_500k runs for SSM/hybrid/local-global, skips pure full-attn."""
    runs = {a for a in ARCHS
            if skip_reason(get_config(a), "long_500k") is None}
    assert runs == {"mamba2-130m", "recurrentgemma-2b", "gemma2-2b"}
    for a in ARCHS:
        assert skip_reason(get_config(a), "train_4k") is None
        assert skip_reason(get_config(a), "decode_32k") is None


def test_input_specs_shapes():
    for arch in ARCHS:
        cfg = get_config(arch)
        for s in supported_shapes(cfg):
            spec = input_specs(cfg, s)
            sh = SHAPES[s]
            assert spec["tokens"].shape[0] == sh.global_batch
            if sh.kind == "decode":
                assert spec["tokens"].shape == (sh.global_batch, 1)
            else:
                assert spec["tokens"].shape[1] == sh.seq_len
            if sh.kind != "decode":
                if cfg.encoder is not None:
                    assert "enc_frames" in spec
                if cfg.vision is not None:
                    assert "prefix_embeds" in spec


def test_segment_compression():
    """Layer patterns compress into few segments (small HLO guarantee)."""
    for arch in ARCHS:
        cfg = get_config(arch)
        segs = segment_layers(cfg.block_specs())
        assert sum(len(b) * r for b, r in segs) == cfg.n_layers
        assert len(segs) <= 3, (arch, len(segs))


def test_make_production_mesh_shapes():
    # NB: under --xla_force_host_platform_device_count this builds real
    # meshes; in the plain test env we only validate the factory's math via
    # the error path (1 CPU device cannot host 256).
    with pytest.raises(ValueError):
        make_production_mesh()


def test_collective_parser_kinds():
    txt = """
  %ag = bf16[32,64]{1,0} all-gather(%p), replica_groups=[16,16]<=[256], dimensions={0}
  %rs = f32[128,128]{1,0} reduce-scatter(%q), replica_groups=[2,8]<=[16], to_apply=%add
  %cp = f32[4,4]{1,0} collective-permute(%r), source_target_pairs={{0,1},{1,0}}
  %noop = f32[4,4]{1,0} collective-permute(%r), source_target_pairs={}
"""
    out = collective_traffic(txt)
    assert out["all-gather"] == pytest.approx(15 / 16 * 32 * 64 * 2)
    assert out["reduce-scatter"] == pytest.approx(7 / 8 * 128 * 128 * 4)
    assert out["collective-permute"] == pytest.approx(4 * 4 * 4)
    assert out["counts"]["all-gather"] == 1


def test_roofline_terms_math():
    rec = {
        "extrapolated": {"flops": 197e12 * 0.5, "bytes": 819e9 * 2.0,
                         "coll_total": 50e9 * 0.25},
        "n_devices": 256,
        "model_flops": 197e12 * 0.25 * 256,
        "memory": {"argument_bytes": 819e9 * 1.0},
    }
    t = roofline_terms(rec)
    assert t["compute_s"] == pytest.approx(0.5)
    assert t["memory_s"] == pytest.approx(2.0)
    assert t["collective_s"] == pytest.approx(0.25)
    assert t["dominant"] == "memory"
    # ideal = max(0.25 compute, 1.0 memory) = 1.0; bound = 2.0
    assert t["roofline_fraction"] == pytest.approx(0.5)
