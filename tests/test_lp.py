"""Unit + property tests for the dense simplex solver (core/lp.py)."""

import itertools

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis; skip where absent
from hypothesis import given, settings, strategies as st

from repro.core.lp import LPInfeasible, LPUnbounded, linprog_max


def test_textbook_max():
    # max 3x + 5y s.t. x <= 4, 2y <= 12, 3x + 2y <= 18  -> (2, 6), obj 36
    res = linprog_max(
        c=[3, 5],
        A_ub=[[1, 0], [0, 2], [3, 2]],
        b_ub=[4, 12, 18],
    )
    assert res.fun == pytest.approx(36.0)
    assert res.x == pytest.approx([2.0, 6.0])
    # duals: y = (0, 3/2, 1)
    assert res.dual_ub == pytest.approx([0.0, 1.5, 1.0])


def test_equality_constraints():
    # max x + 2y s.t. x + y == 1 -> (0, 1), obj 2, dual 2
    res = linprog_max(c=[1, 2], A_eq=[[1, 1]], b_eq=[1])
    assert res.fun == pytest.approx(2.0)
    assert res.x == pytest.approx([0.0, 1.0])
    assert res.dual_eq == pytest.approx([2.0])


def test_infeasible():
    with pytest.raises(LPInfeasible):
        linprog_max(c=[1], A_ub=[[1]], b_ub=[-1], A_eq=[[1]], b_eq=[5])


def test_unbounded():
    with pytest.raises(LPUnbounded):
        linprog_max(c=[1, 0], A_ub=[[0, 1]], b_ub=[1])


def test_degenerate_redundant_rows():
    # Redundant equalities should not break phase 2 / dual recovery.
    res = linprog_max(
        c=[1, 1],
        A_eq=[[1, 1], [2, 2]],
        b_eq=[1, 2],
        A_ub=[[1, 0]],
        b_ub=[0.25],
    )
    assert res.fun == pytest.approx(1.0)


def _brute_force_vertices(c, A_ub, b_ub, tol=1e-9):
    """Enumerate basic feasible vertices of {A x <= b, x >= 0} (tiny LPs)."""
    n = len(c)
    A = np.vstack([A_ub, -np.eye(n)])
    b = np.concatenate([b_ub, np.zeros(n)])
    best = None
    for rows in itertools.combinations(range(A.shape[0]), n):
        M = A[list(rows)]
        if abs(np.linalg.det(M)) < 1e-12:
            continue
        v = np.linalg.solve(M, b[list(rows)])
        if np.all(A @ v <= b + tol):
            val = float(np.dot(c, v))
            if best is None or val > best:
                best = val
    return best


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_matches_vertex_enumeration(data):
    n = data.draw(st.integers(2, 4))
    m = data.draw(st.integers(1, 4))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    c = rng.normal(size=n)
    A = rng.normal(size=(m, n))
    b = rng.uniform(0.5, 2.0, size=m)  # x=0 feasible
    # Bound the polytope so the LP can't be unbounded.
    A = np.vstack([A, np.ones((1, n))])
    b = np.concatenate([b, [5.0]])
    res = linprog_max(c, A, b)
    ref = _brute_force_vertices(c, A, b)
    assert ref is not None
    assert res.fun == pytest.approx(ref, abs=1e-6)
    # Feasibility of returned point.
    assert np.all(A @ res.x <= b + 1e-7)
    assert np.all(res.x >= -1e-9)


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_strong_duality(data):
    """c'x* == b'y* for (feasible, bounded) random instances."""
    n = data.draw(st.integers(2, 4))
    m = data.draw(st.integers(1, 3))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    c = rng.normal(size=n)
    A = np.vstack([rng.normal(size=(m, n)), np.ones((1, n))])
    b = np.concatenate([rng.uniform(0.5, 2.0, size=m), [5.0]])
    res = linprog_max(c, A, b)
    assert float(b @ res.dual_ub) == pytest.approx(res.fun, abs=1e-6)
    # Dual feasibility A'y >= c.
    assert np.all(A.T @ res.dual_ub >= c - 1e-6)
