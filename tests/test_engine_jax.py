"""JAX trace-replay engine: statistical equivalence to the Python
per-server event loop (the semantics oracle), determinism, conservation
laws, budget diagnostics, and the sweep evaluator integration."""

import numpy as np
import pytest

from repro.core.planning import SLISpec, solve_bundled_lp
from repro.core.policies import (baseline_distserve, baseline_sarathi,
                                 baseline_vllm, gate_and_route,
                                 sli_aware_policy)
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass
from repro.data.traces import (TraceConfig, synth_azure_trace,
                               tensorize_trace, trace_class_means)
from repro.serving.engine_jax import ClusterEngineJAX
from repro.serving.engine_sim import ClusterEngine, EngineConfig

pytestmark = pytest.mark.sim

PRIM = ServicePrimitives()
PRICE = Pricing(0.1, 0.2)
N = 10
HORIZON = 40.0


def _mk(seed=42, compression=0.08, horizon=HORIZON):
    trace = synth_azure_trace(
        TraceConfig(horizon=horizon, base_rate=2.0, compression=compression,
                    seed=seed))
    means = trace_class_means(trace, 2)
    classes = [
        WorkloadClass(nm, m[0], m[1], m[2] / N, patience=3e-4)
        for nm, m in zip(("code", "conv"), means)
    ]
    plan = solve_bundled_lp(classes, PRIM, PRICE,
                            sli=SLISpec(pin_zero_decode_queue=True))
    return trace, classes, plan


def _py(trace, classes, pol, horizon=HORIZON, **kw):
    eng = ClusterEngine(classes, pol,
                        EngineConfig(PRIM, PRICE, n_servers=N, seed=1, **kw))
    return eng.run(trace, horizon=horizon).summary()


def _jx(trace, classes, pol, horizon=HORIZON, seed=0, **kw):
    eng = ClusterEngineJAX(classes, pol,
                           EngineConfig(PRIM, PRICE, n_servers=N, **kw),
                           trace, horizon=horizon)
    return eng.run(seed)


def _half_width(vals):
    return 1.96 * np.std(vals, ddof=1) / np.sqrt(len(vals))


@pytest.mark.parametrize("make_policy,kw", [
    (gate_and_route, {}),
    (baseline_vllm, {}),
], ids=["gate_and_route", "vllm"])
def test_statistical_equivalence(make_policy, kw):
    """Mean revenue rate / completions / TTFT agree between the engines
    within 2 CI half-widths over a batch of independent traces.  Both
    engines are deterministic per trace under these policies, so the
    per-trace gap is pure float-ordering drift and tightly bounded too."""
    n_traces = 6
    rev, comp, ttft = [], [], []
    for s in range(n_traces):
        trace, classes, plan = _mk(seed=100 + s)
        m_py = _py(trace, classes, make_policy(plan), **kw)
        m_jx = _jx(trace, classes, make_policy(plan), **kw)
        assert m_jx["budget_exhausted"] == 0.0
        assert m_py["arrivals"] == m_jx["arrivals"]
        # per-trace: deterministic trajectories, small float drift only
        assert m_jx["revenue_rate"] == pytest.approx(
            m_py["revenue_rate"], rel=0.05)
        assert m_jx["completions"] == pytest.approx(
            m_py["completions"], rel=0.05, abs=3)
        rev.append((m_py["revenue_rate"], m_jx["revenue_rate"]))
        comp.append((m_py["completions"], m_jx["completions"]))
        ttft.append((m_py["ttft_mean"], m_jx["ttft_mean"]))
    for pairs in (rev, comp, ttft):
        a = np.array([p[0] for p in pairs])
        b = np.array([p[1] for p in pairs])
        tol = 2.0 * (_half_width(a) + _half_width(b)) + 1e-9
        assert abs(a.mean() - b.mean()) <= tol


def test_equivalence_sarathi_distserve():
    """The baseline family stays faithful too (single-trace spot check;
    DistServe is bitwise-stable enough for a tight tolerance)."""
    trace, classes, plan = _mk(seed=7)
    m_py = _py(trace, classes, baseline_sarathi(plan), sarathi_budget=True)
    m_jx = _jx(trace, classes, baseline_sarathi(plan), sarathi_budget=True)
    assert m_jx["revenue_rate"] == pytest.approx(m_py["revenue_rate"],
                                                 rel=0.05)
    m_py = _py(trace, classes, baseline_distserve(plan, k=4))
    m_jx = _jx(trace, classes, baseline_distserve(plan, k=4))
    assert m_jx["revenue_rate"] == pytest.approx(m_py["revenue_rate"],
                                                 rel=0.01)
    assert m_jx["completions"] == pytest.approx(m_py["completions"], abs=2)


def test_randomized_router_statistical():
    """SLI-aware (randomized router) matches the Python engine across
    replications within CI half-widths -- different PRNG streams, same
    law."""
    trace, classes, plan = _mk(seed=11)
    pol = sli_aware_policy(plan, general=True)
    reps = 8
    r_py = []
    for s in range(reps):
        eng = ClusterEngine(classes, pol,
                            EngineConfig(PRIM, PRICE, n_servers=N, seed=s))
        r_py.append(eng.run(trace, horizon=HORIZON).revenue_rate())
    jeng = ClusterEngineJAX(classes, pol,
                            EngineConfig(PRIM, PRICE, n_servers=N),
                            trace, horizon=HORIZON)
    r_jx = [m["revenue_rate"] for m in jeng.run_batch(range(reps))]
    tol = 2.0 * (_half_width(r_py) + _half_width(r_jx)) + 1e-9
    assert abs(np.mean(r_py) - np.mean(r_jx)) <= tol


def test_determinism_and_batch_consistency():
    trace, classes, plan = _mk(seed=3, compression=0.3)
    pol = sli_aware_policy(plan)  # randomized: seeds actually matter
    jeng = ClusterEngineJAX(classes, pol,
                            EngineConfig(PRIM, PRICE, n_servers=N),
                            trace, horizon=HORIZON)
    a = jeng.run_batch_raw([3, 4])
    b = jeng.run_batch_raw([3, 4])
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    # single-run API agrees with the batched one
    r0 = jeng.run(3)
    assert r0["revenue_rate"] == pytest.approx(
        float(np.asarray(a["rev"])[0]) / jeng.h_eff)


def test_conservation_and_capacity():
    """Every arrival ends the replay in exactly one lifecycle bucket and
    per-server decode residency never exceeds the batch cap."""
    trace, classes, plan = _mk(seed=5)
    jeng = ClusterEngineJAX(classes, gate_and_route(plan),
                            EngineConfig(PRIM, PRICE, n_servers=N),
                            trace, horizon=HORIZON)
    raw = {k: np.asarray(v) for k, v in jeng.run_raw(0).items()}
    st = raw["st"]
    arrived = int((st != 0).sum())
    assert arrived == jeng.trace.valid[
        jeng.trace.t <= jeng.h_eff].sum()
    # all arrived requests are in a live or terminal state (codes 1..6)
    assert np.isin(st[st != 0], [1, 2, 3, 4, 5, 6]).all()
    # the slot arrays and the lifecycle array agree about residency
    slots = raw["slot_rid"]
    resident = slots[slots >= 0]
    assert len(set(resident)) == resident.size  # no rid in two slots
    assert (st[resident] == 4).all()
    assert set(np.nonzero(st == 4)[0]) == set(resident)
    # decode residency within caps; at most one prefill per server
    assert slots.shape == (N, PRIM.batch_cap)
    pf = raw["pf_rid"]
    assert (pf[pf >= 0] < jeng.trace.R).all()
    assert len(set(pf[pf >= 0])) == (pf >= 0).sum()
    assert (st[pf[pf >= 0]] == 2).all()  # prefilling requests match


def test_budget_exhaustion_detected():
    """A max_steps cap below the hard bound is reported, never silent."""
    trace, classes, plan = _mk(seed=5)
    jeng = ClusterEngineJAX(classes, gate_and_route(plan),
                            EngineConfig(PRIM, PRICE, n_servers=N),
                            trace, horizon=HORIZON, max_steps=50)
    m = jeng.run(0)
    assert m["budget_exhausted"] == 1.0
    assert m["t_end"] < jeng.h_eff
    full = ClusterEngineJAX(classes, gate_and_route(plan),
                            EngineConfig(PRIM, PRICE, n_servers=N),
                            trace, horizon=HORIZON)
    assert full.run(0)["budget_exhausted"] == 0.0


def test_max_requests_cap_reported():
    trace, classes, plan = _mk(seed=5)
    jeng = ClusterEngineJAX(classes, gate_and_route(plan),
                            EngineConfig(PRIM, PRICE, n_servers=N),
                            trace, horizon=HORIZON,
                            max_requests=len(trace) // 2)
    assert jeng.trace.n_dropped == len(trace) - len(trace) // 2
    assert jeng.run(0)["n_dropped"] == float(jeng.trace.n_dropped)


def test_unsupported_features_rejected():
    trace, classes, plan = _mk(seed=5)
    with pytest.raises(ValueError, match="record"):
        ClusterEngineJAX(classes, gate_and_route(plan),
                         EngineConfig(PRIM, PRICE, n_servers=N,
                                      record_queues_every=1.0),
                         trace, horizon=HORIZON)


def test_tensorized_trace_input_accepted():
    """A pre-tensorized trace (shared across engines) works as input."""
    trace, classes, plan = _mk(seed=5)
    tt = tensorize_trace(trace)
    a = ClusterEngineJAX(classes, gate_and_route(plan),
                         EngineConfig(PRIM, PRICE, n_servers=N),
                         tt, horizon=HORIZON).run(0)
    b = ClusterEngineJAX(classes, gate_and_route(plan),
                         EngineConfig(PRIM, PRICE, n_servers=N),
                         trace, horizon=HORIZON).run(0)
    assert a == b


def test_sweep_evaluator_integration(tmp_path):
    """The engine_jax evaluator fills the grid with schema-valid cells
    and is deterministic across runs of the same spec."""
    from repro.sweep import SweepSpec, run_sweep
    from repro.sweep.run import default_mix

    mix = default_mix("two_class")
    mix = type(mix)(name=mix.name, classes=mix.classes,
                    trace=dict(horizon=20.0, base_rate=1.0,
                               compression=0.5))
    spec = SweepSpec(name="t_ejax", evaluator="engine_jax",
                     policies=("gate_and_route", "vllm"), n_servers=(4,),
                     n_seeds=2, seed=5, mixes=(mix,),
                     horizon=10.0, warmup=0.0)
    res = run_sweep(spec)
    assert len(res.cells) == spec.n_cells
    m = res.cells[0].metrics
    for key in ("revenue_rate", "completions", "ttft_p95",
                "budget_exhausted", "t_end", "n_iters"):
        assert key in m
    assert m["budget_exhausted"] == 0.0
    assert run_sweep(spec).fingerprint() == res.fingerprint()
    res.save(tmp_path / "t_ejax_sweep.json")  # exercises validate_payload


def test_record_every_rejected_by_evaluator():
    from repro.sweep import SweepSpec, run_sweep
    from repro.sweep.run import default_mix

    spec = SweepSpec(name="t_rec", evaluator="engine_jax",
                     policies=("gate_and_route",), n_servers=(4,),
                     n_seeds=1, mixes=(default_mix("two_class"),),
                     horizon=2.0, record_every=0.5)
    with pytest.raises(ValueError, match="record"):
        run_sweep(spec)
