"""Hypothesis property tests for the new arrival processes.

Split from ``test_workloads.py`` so the deterministic scenario tests run
where hypothesis is absent (same convention as the other property
modules: importorskip at module scope).
"""

import numpy as np
import pytest

from repro.workloads import MMPPArrivals, PiecewiseConstantArrivals

hypothesis = pytest.importorskip(
    "hypothesis")  # property tests need hypothesis; skip where absent
from hypothesis import given, settings, strategies as st  # noqa: E402


@st.composite
def piecewise_specs(draw):
    n_seg = draw(st.integers(1, 6))
    gaps = draw(st.lists(st.floats(1.0, 50.0), min_size=n_seg - 1,
                         max_size=n_seg - 1))
    times = tuple(np.concatenate([[0.0], np.cumsum(gaps)]))
    rates = tuple(draw(st.lists(st.floats(0.0, 30.0), min_size=n_seg,
                                max_size=n_seg)
                       .filter(lambda rs: any(r > 0.5 for r in rs))))
    return PiecewiseConstantArrivals(times=times, rates=rates)


@settings(max_examples=40, deadline=None)
@given(piecewise_specs(), st.integers(0, 2**31 - 1), st.floats(10.0, 200.0))
def test_piecewise_sample_sorted_in_range(proc, seed, horizon):
    ts = proc.sample(np.random.default_rng(seed), horizon)
    assert (np.diff(ts) >= 0).all()
    assert ((ts >= 0) & (ts < horizon)).all()
    # no arrivals inside zero-rate segments
    for j, r in enumerate(proc.rates):
        if r == 0.0:
            hi = proc.times[j + 1] if j + 1 < len(proc.times) else horizon
            assert not ((ts >= proc.times[j]) & (ts < hi)).any()


@settings(max_examples=40, deadline=None)
@given(piecewise_specs(), st.floats(0.0, 300.0), st.floats(0.1, 8.0))
def test_piecewise_rate_at_and_scaling(proc, t, factor):
    j = max(0, np.searchsorted(np.asarray(proc.times), t, side="right") - 1)
    assert proc.rate_at(t) == proc.rates[j]
    scaled = proc.scaled(factor)
    assert scaled.times == proc.times  # breakpoints stay authored
    assert scaled.rate_at(t) == pytest.approx(factor * proc.rate_at(t))
    assert scaled.mean_rate(100.0) == pytest.approx(
        factor * proc.mean_rate(100.0))


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 5), st.integers(0, 2**31 - 1))
def test_mmpp_k_regimes_sample_properties(k, seed):
    proc = MMPPArrivals(base_rate=5.0,
                        levels=tuple(0.5 + i for i in range(k)),
                        switch=tuple(1 / 10.0 for _ in range(k)))
    ts = proc.sample(np.random.default_rng(seed), 50.0)
    assert (np.diff(ts) > 0).all()
    assert ((ts >= 0) & (ts < 50.0)).all()
    assert proc.rate_bound() == pytest.approx(5.0 * (k - 0.5))
    # stationary mean with equal holding times = plain average of levels
    assert proc.mean_rate(50.0) == pytest.approx(
        5.0 * np.mean([0.5 + i for i in range(k)]))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(20.0, 120.0),
       st.floats(0.05, 0.95))
def test_mix_schedule_shares_property(seed, horizon, share0):
    """Scenario class draws follow the scheduled shares in every phase."""
    from repro.data.traces import ClassProfile
    from repro.workloads import PoissonArrivals, Scenario

    scn = Scenario(
        name="prop", description="",
        profiles=(ClassProfile("a", 50, 10, share=share0),
                  ClassProfile("b", 50, 10, share=1 - share0)),
        arrivals=PoissonArrivals(rate=40.0),
        horizon=horizon,
        mix_schedule=((horizon / 2, (1 - share0, share0)),))
    trace = scn.generate(seed=seed)
    pre = [r.cls for r in trace if r.t_arrival < horizon / 2]
    post = [r.cls for r in trace if r.t_arrival >= horizon / 2]
    if len(pre) > 50:
        assert abs(np.mean(pre) - (1 - share0)) < 0.2
    if len(post) > 50:
        assert abs(np.mean(post) - share0) < 0.2
