"""Closed-loop harness + online-controller coverage on scenarios.

Covers the ISSUE's controller satellites: ``estimate_rates`` tracks a
rate-shift scenario within tolerance, ``set_capacity`` replans
immediately, and the closed-loop harness is deterministic given a seed
(plus a functional smoke: the loop actually adapts -- replans fire and
the cold-frozen plan is beaten on the shift scenario).
"""

import numpy as np
import pytest

from repro.core.online import OnlineController, OnlineControllerConfig
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass
from repro.data.traces import trace_class_means
from repro.workloads import (ClosedLoopConfig, compare_policies,
                             get_scenario, run_closed_loop)

pytestmark = pytest.mark.sim

PRIM = ServicePrimitives()
PRICING = Pricing()

N = 6
QUICK = ClosedLoopConfig(n_servers=N, seed=0, rate_scale=0.5, horizon=60.0)


def _controller(classes, safety=1.0, window=30.0):
    return OnlineController(
        classes, PRIM, PRICING, n=N,
        config=OnlineControllerConfig(window=window, safety=safety))


def test_estimate_rates_tracks_rate_shift_scenario():
    """Feed the rate_shift scenario's arrivals straight into the
    estimator; after the shift (plus one window) the estimate must match
    the post-shift truth within tolerance, per class."""
    scn = get_scenario("rate_shift")
    trace = scn.generate(seed=1)
    means = trace_class_means(trace, scn.n_classes)
    classes = [WorkloadClass(f"c{i}", means[i][0], means[i][1],
                             means[i][2] / N, 3e-4)
               for i in range(scn.n_classes)]
    ctrl = _controller(classes, safety=1.0, window=30.0)

    shift_t = 120.0
    pre = [r for r in trace if r.t_arrival < shift_t]
    post = [r for r in trace if r.t_arrival >= shift_t]
    for r in pre:
        ctrl.observe_arrival(r.t_arrival, r.cls)
    lam_pre = ctrl.estimate_rates(shift_t) * N  # cluster level
    for r in post:
        ctrl.observe_arrival(r.t_arrival, r.cls)
    t_end = trace[-1].t_arrival
    lam_post = ctrl.estimate_rates(t_end) * N

    true_pre = np.array([sum(1 for r in pre if r.cls == i) / shift_t
                         for i in range(scn.n_classes)])
    true_post = np.array(
        [sum(1 for r in post if t_end - r.t_arrival <= 30.0 and r.cls == i)
         / 30.0 for i in range(scn.n_classes)])
    np.testing.assert_allclose(lam_pre, true_pre, rtol=0.35)
    np.testing.assert_allclose(lam_post, true_post, rtol=0.35)
    # the estimator saw the regime change: class-1 rate way up
    assert lam_post[1] > 2.0 * lam_pre[1]


def test_set_capacity_triggers_immediate_replan():
    classes = [WorkloadClass("a", 2048, 36, 0.5, 3e-4),
               WorkloadClass("b", 1020, 211, 0.5, 3e-4)]
    ctrl = _controller(classes)
    ctrl.maybe_replan(0.0)
    before = ctrl.replan_count
    ctrl.set_capacity(N - 2, t=1.0)  # failure: replan NOW, not at the epoch
    assert ctrl.replan_count == before + 1
    assert ctrl.n == N - 2
    assert ctrl.mixed_target() <= N - 2
    ctrl.set_capacity(N - 2, t=2.0)  # no-op: capacity unchanged
    assert ctrl.replan_count == before + 1


def test_closed_loop_deterministic_given_seed():
    a = run_closed_loop("rate_shift", "adaptive", QUICK)
    b = run_closed_loop("rate_shift", "adaptive", QUICK)
    assert a == b
    c = run_closed_loop("rate_shift", "adaptive",
                        ClosedLoopConfig(n_servers=N, seed=1, rate_scale=0.5,
                                         horizon=60.0))
    assert a != c


def test_compare_policies_pairs_variants_on_one_trace():
    res = compare_policies("rate_shift", QUICK,
                           variants=("adaptive", "static_cold"))
    va = res["variants"]["adaptive"]
    vc = res["variants"]["static_cold"]
    assert va["arrivals"] == vc["arrivals"] == res["n_requests"]
    assert va["completions"] > 0 and vc["completions"] > 0
    assert va["replans"] > 0 and vc["replans"] == 0


def test_closed_loop_adapts_through_the_shift():
    """Full-length rate_shift: the controller must beat the plan frozen
    at cold start (the deployment the paper's Section 6.2 fixes)."""
    cfg = ClosedLoopConfig(n_servers=N, seed=0, rate_scale=0.6)
    res = compare_policies("rate_shift", cfg,
                           variants=("adaptive", "static_cold"))
    va = res["variants"]["adaptive"]
    vc = res["variants"]["static_cold"]
    assert va["replans"] >= 10  # epochs fired across the horizon
    assert va["revenue_rate"] > vc["revenue_rate"]
    assert va["completion_rate"] >= vc["completion_rate"]


def test_capacity_churn_scenario_drives_elastic_replans():
    cfg = ClosedLoopConfig(n_servers=N, seed=0, rate_scale=0.4,
                           horizon=120.0)
    m = run_closed_loop("capacity_churn", "adaptive", cfg)
    # epoch replans + at least the two failure and one recovery replans
    assert m["replans"] > 120.0 / 10.0
    assert m["completions"] > 0


def test_set_link_replans_without_capacity_change():
    """A ``degrade`` event shifts transfer-adjusted service rates but not
    the server count, so ``set_capacity`` would no-op -- the engine must
    replan directly, and restoring the link must replan again."""
    from repro.core.planning import solve_bundled_lp
    from repro.core.policies import gate_and_route
    from repro.serving.engine_sim import ClusterEngine, EngineConfig

    classes = [WorkloadClass("a", 2048, 36, 0.5, 3e-4),
               WorkloadClass("b", 1020, 211, 0.5, 3e-4)]
    plan = solve_bundled_lp(classes, PRIM, PRICING)
    ctrl = _controller(classes)
    eng = ClusterEngine(classes, gate_and_route(plan),
                        EngineConfig(PRIM, PRICING, N, seed=0),
                        controller=ctrl)
    before = ctrl.replan_count
    eng.set_link(2, 0.25)  # brownout: 1/4 of nominal bandwidth left
    assert ctrl.replan_count == before + 1
    assert eng.servers[2].link_scale == 0.25
    assert ctrl.n == N  # capacity unchanged -- this was NOT set_capacity
    eng.set_link(2, 1.0)  # recovery replans too
    assert ctrl.replan_count == before + 2
    assert eng.servers[2].link_scale == 1.0
    with pytest.raises(ValueError, match="link scale"):
        eng.set_link(2, 0.0)


def test_link_degrade_scenario_replans_and_recovers():
    """link_degrade end-to-end: the closed loop replays the degrade +
    restore script (6 extra replans on top of the control epochs) and
    keeps completing work through the brownout window."""
    cfg = ClosedLoopConfig(n_servers=N, seed=0, rate_scale=0.4,
                           horizon=200.0)
    m = run_closed_loop("link_degrade", "adaptive", cfg)
    # epoch replans + the three degrade and three restore replans
    assert m["replans"] > 200.0 / 10.0
    assert m["completions"] > 0


def test_unknown_variant_rejected():
    with pytest.raises(ValueError, match="variant"):
        run_closed_loop("rate_shift", "zeppelin", QUICK)


def test_total_outage_does_not_crash_controller():
    """capacity_churn on a 2-server cluster kills EVERY server at t=60;
    the controller must keep replanning (n == 0 guard) and the cluster
    must recover and complete work once servers rejoin."""
    classes = [WorkloadClass("a", 2048, 36, 0.5, 3e-4),
               WorkloadClass("b", 1020, 211, 0.5, 3e-4)]
    ctrl = _controller(classes)
    ctrl.set_capacity(0, t=1.0)  # direct unit guard: no ZeroDivisionError
    assert np.isfinite(ctrl.estimate_rates(2.0)).all()
    assert ctrl.mixed_target() == 0

    cfg = ClosedLoopConfig(n_servers=2, seed=0, rate_scale=0.25,
                           horizon=200.0)
    m = run_closed_loop("capacity_churn", "adaptive", cfg)
    assert m["completions"] > 0
    assert m["replans"] > 0
