"""SPMD sharding layer: plan/padding invariants, the unified evaluator
registry, and device-count invariance of the sharded runner.

The expensive guarantee -- ``placement="shard_map"`` bitwise-equal to
the single-device ``vmap`` oracle on a REAL multi-device mesh -- runs in
a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the flag must be set before jax imports), on a grid whose cell count
does not divide the mesh, so ragged padding/masking is exercised at the
same time.
"""

import json
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.sweep.sharded import (PLACEMENTS, ShardPlan, pad_batch,
                                 plan_shards, run_sharded)
from repro.sweep.spec import EVALUATORS, SweepSpec, get_evaluator

# ---------------------------------------------------------------------------
# plan_shards / ShardPlan invariants (manual property sweep; seeded)
# ---------------------------------------------------------------------------


def test_plan_shards_invariants():
    rng = np.random.default_rng(0)
    for _ in range(200):
        n_cells = int(rng.integers(1, 500))
        d = int(rng.integers(1, 17))
        cap = int(rng.integers(1, 64)) if rng.random() < 0.5 else None
        plan = plan_shards(n_cells, n_devices=d, max_cells_per_device=cap)
        # every cell is covered, in whole equal-shape tiles
        assert plan.padded >= n_cells
        assert plan.padded == plan.n_tiles * plan.tile
        assert plan.tile == plan.n_devices * plan.per_device
        assert plan.n_padding == plan.padded - n_cells
        assert plan.n_padding < plan.tile  # never a whole wasted tile
        if cap is not None:
            assert plan.per_device <= cap
        else:
            assert plan.n_tiles == 1  # uncapped: one pass
        r = plan.report()
        assert r["n_cells"] == n_cells and r["n_devices"] == d


def test_plan_shards_memory_budget():
    # cap derived from a per-cell footprint: floor(budget / bytes)
    plan = plan_shards(100, n_devices=4, bytes_per_cell=1000.0,
                       memory_budget=3500.0)
    assert plan.per_device == 3
    # explicit cap wins when tighter
    plan = plan_shards(100, n_devices=4, max_cells_per_device=2,
                       bytes_per_cell=1000.0, memory_budget=3500.0)
    assert plan.per_device == 2


def test_plan_shards_rejects_degenerate():
    with pytest.raises(ValueError):
        plan_shards(0, n_devices=2)
    with pytest.raises(ValueError):
        plan_shards(4, n_devices=2, max_cells_per_device=0)
    with pytest.raises(ValueError):
        plan_shards(4, n_devices=2, bytes_per_cell=-1.0, memory_budget=8.0)
    with pytest.raises(ValueError):
        ShardPlan(n_cells=4, n_devices=0, per_device=1)


def test_pad_batch_repeats_cell_zero():
    import jax.numpy as jnp

    rng = np.random.default_rng(1)
    for _ in range(20):
        n = int(rng.integers(1, 12))
        padded = n + int(rng.integers(0, 7))
        tree = {"a": jnp.asarray(rng.normal(size=(n, 3))),
                "b": jnp.asarray(rng.integers(0, 9, size=(n,)))}
        out = pad_batch(tree, padded)
        for k in tree:
            got = np.asarray(out[k])
            assert got.shape[0] == padded
            np.testing.assert_array_equal(got[:n], np.asarray(tree[k]))
            for j in range(n, padded):  # padding lanes repeat cell 0
                np.testing.assert_array_equal(got[j], got[0])


# ---------------------------------------------------------------------------
# the unified evaluator registry
# ---------------------------------------------------------------------------


def test_every_evaluator_name_registers():
    for name in EVALUATORS:
        ev = get_evaluator(name)
        assert ev.name == name
        assert callable(ev.fn)
    with pytest.raises(Exception):
        get_evaluator("no_such_evaluator")


def test_deterministic_flags_and_prepare_hooks():
    assert get_evaluator("lp").deterministic
    assert get_evaluator("fluid").deterministic
    assert get_evaluator("lp_jax").deterministic
    assert get_evaluator("fluid").prepare is not None
    assert get_evaluator("lp_jax").prepare is not None
    for name in ("ctmc", "ctmc_jax", "engine", "engine_jax"):
        assert not get_evaluator(name).deterministic


def test_deprecated_shims_warn_and_agree():
    from repro.sweep.evaluators import MixContext, evaluate_lp_cell
    from repro.sweep.run import default_mix

    spec = SweepSpec(name="t", evaluator="lp", policies=("lp",),
                     n_servers=(10,), mixes=(default_mix(),))
    ctx = MixContext(default_mix(), spec)
    with pytest.warns(DeprecationWarning):
        legacy = evaluate_lp_cell(ctx, "lp")
    cells = get_evaluator("lp")(ctx, "lp", 10, seeds=[None, None])
    assert len(cells) == 2  # deterministic dict replicated per seed
    assert cells[0].metrics == legacy


def test_get_evaluator_unknown_name_lists_known():
    """The dispatch error must name every registered evaluator, so a
    typo'd spec.evaluator is self-diagnosing."""
    with pytest.raises(Exception, match="no evaluator registered") as exc:
        get_evaluator("no_such_evaluator")
    msg = str(exc.value)
    for name in EVALUATORS:
        assert name in msg, f"{name} missing from: {msg}"


def test_all_deprecated_shims_warn_and_agree():
    """Every legacy ``evaluate_*`` entry point must (a) emit a
    DeprecationWarning pointing at ``get_evaluator`` and (b) return
    results identical to the registered Evaluator it wraps."""
    from repro.sweep.evaluators import (MixContext, evaluate_ctmc_cells,
                                        evaluate_ctmc_jax_cells,
                                        evaluate_engine_cell,
                                        evaluate_engine_jax_cells,
                                        evaluate_lp_cell,
                                        evaluate_lp_jax_grid)
    from repro.sweep.run import default_mix
    from repro.sweep.spec import cell_seed_sequence

    mix = default_mix("two_class")
    spec = SweepSpec(name="t", evaluator="ctmc",
                     policies=("gate_and_route",), n_servers=(4,),
                     n_seeds=2, seed=7, mixes=(mix,),
                     horizon=6.0, warmup=1.0)
    n = 4
    token = "gate_and_route"
    streams = [cell_seed_sequence(spec, 0, 0, 0, s) for s in range(2)]

    def fresh_ctx():
        return MixContext(mix, spec)

    # seed-replicated stochastic shims: (shim, registered name)
    for shim, name in ((evaluate_ctmc_cells, "ctmc"),
                       (evaluate_ctmc_jax_cells, "ctmc_jax"),
                       (evaluate_engine_jax_cells, "engine_jax")):
        with pytest.warns(DeprecationWarning, match="get_evaluator"):
            legacy = shim(fresh_ctx(), token, n, streams)
        cells = get_evaluator(name)(fresh_ctx(), token, n, seeds=streams)
        assert len(legacy) == len(cells) == 2
        for old, new in zip(legacy, cells):
            assert dict(old) == new.metrics, name

    # single-seed Python trace engine shim
    with pytest.warns(DeprecationWarning, match="get_evaluator"):
        legacy = evaluate_engine_cell(fresh_ctx(), token, n, streams[0])
    (cell,) = get_evaluator("engine")(fresh_ctx(), token, n,
                                      seeds=streams[:1])
    assert dict(legacy) == cell.metrics

    # deterministic planners: no seed axis
    with pytest.warns(DeprecationWarning, match="get_evaluator"):
        legacy = evaluate_lp_cell(fresh_ctx(), "lp")
    (cell,) = get_evaluator("lp")(fresh_ctx(), "lp", n, seeds=[None])
    assert legacy == cell.metrics

    ctx = fresh_ctx()
    with pytest.warns(DeprecationWarning, match="get_evaluator"):
        grid = evaluate_lp_jax_grid([ctx], ["lp"])
    (cell,) = get_evaluator("lp_jax")(fresh_ctx(), "lp", n, seeds=[None])
    assert grid[(0, 0)] == cell.metrics


def test_run_sweep_rejects_unknown_placement():
    from repro.sweep import run_sweep
    from repro.sweep.run import default_mix

    spec = SweepSpec(name="t", evaluator="lp", policies=("lp",),
                     n_servers=(10,), mixes=(default_mix(),),
                     extra={"placement": "warp_drive"})
    with pytest.raises(ValueError, match="placement"):
        run_sweep(spec)


# ---------------------------------------------------------------------------
# sharded runner vs the vmap oracle (1 device in-process, 8 forced in a
# subprocess)
# ---------------------------------------------------------------------------


def _toy_kernel_case(n_cells):
    import jax
    import jax.numpy as jnp

    def kernel(rep, item):
        key, x = item
        noise = jax.random.normal(key, x.shape)
        return {"y": jnp.cumsum(rep["w"] * x + noise),
                "s": jnp.sum(x) + rep["b"]}

    rep = {"w": jnp.asarray(1.5), "b": jnp.asarray(-0.25)}
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(n_cells))
    xs = jnp.linspace(0.0, 1.0, n_cells * 4).reshape(n_cells, 4)
    return kernel, rep, (keys, xs)


@pytest.mark.sim
def test_run_sharded_matches_vmap_one_device():
    import jax
    from repro import compat

    kernel, rep, batched = _toy_kernel_case(5)
    # the oracle is the JITTED vmap -- what the engines actually run
    # (eager vmap may fuse float math differently; bitwise claims are
    # always jit-vs-jit)
    oracle = jax.jit(jax.vmap(lambda k, x: kernel(rep, (k, x))))(*batched)

    compat.reset_warn_once("shard-serial")
    with pytest.warns(RuntimeWarning, match="1-device mesh"):
        raw, report = run_sharded(kernel, rep, batched, n_devices=1)
    assert report["serialized"] and report["n_devices"] == 1
    for k in ("y", "s"):
        np.testing.assert_array_equal(np.asarray(raw[k]),
                                      np.asarray(oracle[k]))

    # the per-process dedupe: the "shard-serial" kind is spent, so a
    # second serialized run (and the compat shim, which shares the kind)
    # stays quiet instead of warning once per layer per call
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        run_sharded(kernel, rep, batched, n_devices=1)
    assert not [x for x in w if issubclass(x.category, RuntimeWarning)
                and "1-device mesh" in str(x.message)]


@pytest.mark.sim
def test_run_sharded_tiling_matches_vmap():
    import jax

    # 7 cells, cap 2 per device -> multiple tiles + ragged padding
    kernel, rep, batched = _toy_kernel_case(7)
    oracle = jax.jit(jax.vmap(lambda k, x: kernel(rep, (k, x))))(*batched)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        raw, report = run_sharded(kernel, rep, batched, n_devices=1,
                                  max_cells_per_device=2)
    assert report["n_tiles"] == 4 and report["n_padding"] == 1
    for k in ("y", "s"):
        np.testing.assert_array_equal(np.asarray(raw[k]),
                                      np.asarray(oracle[k]))


@pytest.mark.sim
def test_ctmc_jax_x64_extra():
    # extra["ctmc_jax"]["x64"] scopes the whole cell in double precision
    # (the gap study needs it: the float32 clock stalls at production n)
    import jax.numpy as jnp

    from repro.compat import enable_x64
    from repro.core.ctmc_jax import UniformizedCTMC
    from repro.sweep.evaluators import MixContext, resolve_policy
    from repro.sweep.run import default_mix
    from repro.sweep.spec import cell_seed_sequence

    spec = SweepSpec(name="t", evaluator="ctmc_jax",
                     policies=("gate_and_route",), n_servers=(10,),
                     n_seeds=2, mixes=(default_mix(),), horizon=3.0,
                     warmup=1.0, extra={"ctmc_jax": {"x64": True}})
    ctx = MixContext(default_mix(), spec)
    with enable_x64():
        sim = UniformizedCTMC(ctx.classes, ctx.prim, ctx.pricing,
                              resolve_policy("gate_and_route", ctx, 10),
                              n=10, horizon=3.0, warmup=1.0)
        assert sim.params["lam_tot"].dtype == jnp.float64
    streams = [cell_seed_sequence(spec, 0, 0, 0, si) for si in range(2)]
    cells = get_evaluator("ctmc_jax")(ctx, "gate_and_route", 10,
                                      seeds=streams)
    assert all(c.metrics["t_end"] == 3.0 for c in cells)
    assert all(np.isfinite(c.metrics["revenue_rate"]) for c in cells)


@pytest.mark.sim
def test_engine_jax_facade_placements_agree():
    from repro.sweep.evaluators import MixContext
    from repro.sweep.spec import MixSpec, cell_seed_sequence

    mix = MixSpec(name="tr", trace=dict(horizon=3.0, seed=1,
                                        compression=0.02))
    spec = SweepSpec(name="t", evaluator="engine_jax", policies=("vllm",),
                     n_servers=(8,), n_seeds=4, mixes=(mix,),
                     horizon=3.0, warmup=0.5)
    ctx = MixContext(mix, spec)
    streams = [cell_seed_sequence(spec, 0, 0, 0, si) for si in range(4)]
    ev = get_evaluator("engine_jax")
    ref = ev(ctx, "vllm", 8, seeds=streams, placement="vmap")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        shd = ev(ctx, "vllm", 8, seeds=streams, placement="shard_map")
    assert [c.metrics for c in shd] == [c.metrics for c in ref]


# the full device-count-invariance guarantee: 8 forced host devices, a
# 5-cell grid (ragged on the mesh), bitwise equality with the oracle
SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, warnings
import jax
assert jax.device_count() == 8, jax.devices()
from repro.sweep import SweepSpec, run_sweep
from repro.sweep.run import default_mix

spec = SweepSpec(name="t", evaluator="ctmc_jax",
                 policies=("gate_and_route",), n_servers=(10,), n_seeds=5,
                 mixes=(default_mix(),), horizon=3.0, warmup=1.0,
                 extra={"placement": "shard_map"})
with warnings.catch_warnings():
    warnings.simplefilter("ignore")
    res = run_sweep(spec)
assert res.meta["shard_devices"] == 8, res.meta
print("CELLS=" + json.dumps([c.metrics for c in res.cells]))
"""


@pytest.mark.sim
def test_shard_map_eight_devices_matches_vmap_oracle():
    from repro.sweep import run_sweep
    from repro.sweep.run import default_mix

    spec = SweepSpec(name="t", evaluator="ctmc_jax",
                     policies=("gate_and_route",), n_servers=(10,),
                     n_seeds=5, mixes=(default_mix(),), horizon=3.0,
                     warmup=1.0, extra={"placement": "vmap"})
    oracle = run_sweep(spec)

    r = subprocess.run([sys.executable, "-c", SUBPROC], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "CELLS=" in r.stdout, r.stdout + r.stderr
    line = next(l for l in r.stdout.splitlines() if l.startswith("CELLS="))
    sharded_metrics = json.loads(line[len("CELLS="):])
    assert sharded_metrics == [c.metrics for c in oracle.cells]
